//! Static cycle & traffic predictor: exact simulation results without the
//! simulator.
//!
//! The scoreboard of [`crate::sim::Processor`] is a deterministic monotone
//! system: every instruction's issue/start/complete time is a pure function
//! of the decode clock, the per-FU free times, the per-vreg hazard tables,
//! the MPTU chain register, and the shared memory-port free time — and each
//! of those only ever advances. None of them depends on *data* values, only
//! on control state (`vl`/`sew`/precision) and scalar address registers,
//! both of which compiled streams set through `ADDI`/`VSETVLI`/`VSACFG`
//! with immediate operands. A compiled operator's cycle count is therefore
//! computable by abstract interpretation alone: replay the scoreboard
//! recurrence per instruction, skip the functional work (VRF bytes, MAC
//! numerics, memory contents), and the frontier arithmetic reproduces the
//! simulator's timing *bit for bit* — not an estimate.
//!
//! Concretely, with `ready = decode + 1` and monotone state `F` (FU free),
//! `H` (hazard tables), `P` (memory port), the per-instruction recurrence
//! is
//!
//! ```text
//! issue    = max(ready, F[fu], H[reads ∪ writes])
//! start    = if bytes > 0 { max(issue, P) } else { issue }
//! complete = start + ex
//! cycles  += max(complete, frontier) - frontier        (bucketed by class)
//! ```
//!
//! with the MPTU chain discount `ex -= PIPE_FILL` whenever
//! `issue <= last_mptu_complete`. [`CostModel`] implements exactly this,
//! and [`cost_op`] runs it over an operator's compiled stream. The
//! `static_cost` tier-2 property test proves the resulting
//! [`SimStats`] *and* [`CycleBreakdown`] equal batch-mode execution
//! bit-identically over random shapes × all precisions × feasible
//! strategies (exact and batch mode already agree by the fast-path parity
//! contract).
//!
//! The predictor assumes the stream it replays is *legal* — run it after
//! (or alongside) [`crate::analysis::verify_segments`]; the auto-tuner
//! does exactly that before using static costs to prune its search.

use crate::compiler::{self, MemLayout};
use crate::config::SpeedConfig;
use crate::dataflow::MappingChoice;
use crate::error::SpeedError;
use crate::isa::{Insn, WidthSel};
use crate::models::ops::OpDesc;
use crate::obs::CycleBreakdown;
use crate::sim::mptu::PIPE_FILL;
use crate::sim::{CtrlState, Fu, OpPlan, SimStats, TrafficClass, TrafficStats};

/// The statically predicted execution profile of one compiled operator:
/// bit-identical to what [`crate::engine::Engine::run_op_with`] would
/// report for the same `(op, choice)` on a quiesced engine.
#[derive(Debug, Clone)]
pub struct StaticCost {
    /// Predicted run statistics (cycles, stalls, traffic, MACs, ...).
    pub stats: SimStats,
    /// Predicted cycle attribution; `breakdown.total() == stats.cycles`.
    pub breakdown: CycleBreakdown,
    /// True when the mapping's partial sums do not fit the VRF partial
    /// partition ([`crate::dataflow::Mapping::partials_in_vrf`] is false):
    /// the stream's spill/reload round-trips are real traffic already
    /// inside `stats`, and the flag lets tuner reports and the
    /// `L-RES-01` lint surface the residency loss explicitly.
    pub partials_spilled: bool,
}

impl StaticCost {
    /// The auto-tuner's cost tuple: simulated cycles first, total
    /// external-memory traffic as the tie-break.
    pub fn cost(&self) -> (u64, u64) {
        (self.stats.cycles, self.stats.traffic.total())
    }
}

/// Abstract interpreter replaying the processor's issue/execute scoreboard
/// over a compiled stream without functional execution.
///
/// The model starts from the fresh-machine state ([`CtrlState::default`],
/// drained pipeline) — the same state a quiesced engine runs each tuning
/// candidate from, which is what makes the prediction exact rather than
/// approximate. Feed whole segments in program order via
/// [`CostModel::run_segment`]; the per-segment stats epilogue (cycle
/// clamp, overhead residue, traffic deltas) mirrors the simulator's, so
/// merged multi-segment totals line up too.
pub struct CostModel {
    cfg: SpeedConfig,
    plan: OpPlan,
    // ---- scoreboard state (mirrors `Processor`, times in cycles) ----
    t_decode: u64,
    fu_free: [u64; 5],
    mem_port_free: u64,
    vreg_write_done: [u64; 32],
    vreg_read_done: [u64; 32],
    last_mptu_complete: u64,
    last_complete: u64,
    vregs_touched: [bool; 32],
    // ---- architectural state the timing depends on ----
    ctrl: CtrlState,
    xregs: [i64; 32],
    stage_cursor: u64,
    traffic: TrafficStats,
    // ---- accumulated outputs ----
    stats: SimStats,
    breakdown: CycleBreakdown,
}

impl CostModel {
    /// A model for one operator execution under `plan`, from the
    /// fresh-machine entry state.
    pub fn new(cfg: SpeedConfig, plan: OpPlan) -> Self {
        CostModel {
            cfg,
            plan,
            t_decode: 0,
            fu_free: [0; 5],
            mem_port_free: 0,
            vreg_write_done: [0; 32],
            vreg_read_done: [0; 32],
            last_mptu_complete: u64::MAX,
            last_complete: 0,
            vregs_touched: [false; 32],
            ctrl: CtrlState::default(),
            xregs: [0; 32],
            stage_cursor: 0,
            traffic: TrafficStats::default(),
            stats: SimStats::default(),
            breakdown: CycleBreakdown::default(),
        }
    }

    /// Replay one segment, accumulating its predicted stats (the same
    /// per-run epilogue `Processor::run_insns` applies: ≥ 1-cycle clamp,
    /// overhead residue, per-class traffic delta).
    pub fn run_segment(&mut self, insns: &[Insn]) {
        let start_traffic = self.traffic;
        let start_switches = self.ctrl.precision_switches;
        let mut run_stats = SimStats::default();
        let run_begin = self.last_complete;
        let attr_begin = self.breakdown.total();

        for insn in insns {
            self.step(insn, &mut run_stats);
        }

        run_stats.cycles = (self.last_complete + 1).saturating_sub(run_begin + 1).max(1);
        let attributed = self.breakdown.total() - attr_begin;
        self.breakdown.overhead += run_stats.cycles - attributed.min(run_stats.cycles);
        run_stats.vregs_used = self.vregs_touched.iter().filter(|&&b| b).count() as u32;
        run_stats.precision_switches = self.ctrl.precision_switches - start_switches;
        let t = self.traffic;
        run_stats.traffic.input_read = t.input_read - start_traffic.input_read;
        run_stats.traffic.weight_read = t.weight_read - start_traffic.weight_read;
        run_stats.traffic.partial_read = t.partial_read - start_traffic.partial_read;
        run_stats.traffic.partial_write = t.partial_write - start_traffic.partial_write;
        run_stats.traffic.output_write = t.output_write - start_traffic.output_write;
        self.stats.merge(&run_stats);
    }

    /// Consume the model, returning the accumulated prediction. The
    /// model only replays a stream, so the geometric `partials_spilled`
    /// flag starts false; [`cost_op`] fills it from the mapping.
    pub fn finish(self) -> StaticCost {
        StaticCost { stats: self.stats, breakdown: self.breakdown, partials_spilled: false }
    }

    fn xreg(&self, r: u8) -> i64 {
        if r == 0 {
            0
        } else {
            self.xregs[r as usize]
        }
    }

    fn step(&mut self, insn: &Insn, st: &mut SimStats) {
        let decode_t = self.t_decode;
        self.t_decode += 1;
        st.insns_total += 1;
        if insn.is_custom() {
            st.insns_custom += 1;
        }
        if insn.is_vector() {
            st.insns_vector += 1;
        } else {
            st.insns_scalar += 1;
        }
        let reads = insn.vregs_read();
        let writes = insn.vregs_written();
        for r in reads.iter().chain(writes.iter()) {
            self.vregs_touched[*r as usize] = true;
        }
        let (fu, ex_cycles, port_bytes) = self.cost_of(insn);
        self.schedule(insn, decode_t, fu, ex_cycles, port_bytes, &reads, &writes, st);
        self.effects(insn, st);
    }

    /// The scoreboard advance of one classified instruction — the
    /// frontier recurrence from the module docs, matching
    /// `Processor::schedule` term for term.
    #[allow(clippy::too_many_arguments)]
    fn schedule(
        &mut self,
        insn: &Insn,
        decode_t: u64,
        fu: Fu,
        mut ex_cycles: u64,
        port_bytes: u64,
        reads: &[u8],
        writes: &[u8],
        st: &mut SimStats,
    ) {
        let ready = decode_t + 1;
        let mut issue = ready.max(self.fu_free[fu.index()]);
        if self.fu_free[fu.index()] > ready {
            st.stall_fu_busy += self.fu_free[fu.index()] - ready;
        }
        let mut hazard_until = 0u64;
        for &r in reads {
            hazard_until = hazard_until.max(self.vreg_write_done[r as usize]);
        }
        for &r in writes {
            hazard_until = hazard_until.max(self.vreg_write_done[r as usize]);
            hazard_until = hazard_until.max(self.vreg_read_done[r as usize]);
        }
        if hazard_until > issue {
            st.stall_hazard += hazard_until - issue;
            issue = hazard_until;
        }
        if fu == Fu::Mptu {
            if issue <= self.last_mptu_complete {
                ex_cycles = ex_cycles.saturating_sub(PIPE_FILL).max(1);
            }
            self.last_mptu_complete = issue.max(self.fu_free[fu.index()]) + ex_cycles;
        }
        let mut start = issue;
        if port_bytes > 0 {
            if self.mem_port_free > start {
                st.stall_mem_port += self.mem_port_free - start;
                start = self.mem_port_free;
            }
            self.mem_port_free = start + ex_cycles;
        }
        let complete = start + ex_cycles;
        self.fu_free[fu.index()] = complete;
        for &r in writes {
            self.vreg_write_done[r as usize] = complete;
        }
        for &r in reads {
            self.vreg_read_done[r as usize] = self.vreg_read_done[r as usize].max(complete);
        }
        st.fu_busy[fu.index()] += ex_cycles;
        let frontier_was = self.last_complete;
        self.last_complete = self.last_complete.max(complete);
        self.attribute(insn, self.last_complete - frontier_was);
    }

    fn attribute(&mut self, insn: &Insn, delta: u64) {
        if delta == 0 {
            return;
        }
        match *insn {
            Insn::Vsam { .. } | Insn::Vsac { .. } => self.breakdown.chain += delta,
            Insn::Vle { .. } | Insn::Vsald { .. } => self.breakdown.load += delta,
            Insn::Vse { .. } => self.breakdown.store += delta,
            Insn::Vmacc { .. }
            | Insn::Vmul { .. }
            | Insn::Vadd { .. }
            | Insn::Vsub { .. }
            | Insn::Vmax { .. }
            | Insn::Vmin { .. }
            | Insn::Vsra { .. }
            | Insn::Vmv { .. } => self.breakdown.alu += delta,
            Insn::Vsacfg { zimm, .. } => {
                // Classified against the pre-apply precision, like the
                // simulator (schedule runs before the config latches).
                if Insn::unpack_cfg(zimm).is_some_and(|(p, _, _)| p != self.ctrl.prec) {
                    self.breakdown.prec_switch += delta;
                } else {
                    self.breakdown.scalar += delta;
                }
            }
            Insn::Addi { .. } | Insn::Vsetvli { .. } | Insn::VsacfgDim { .. } => {
                self.breakdown.scalar += delta;
            }
        }
    }

    /// (FU, EX cycles, memory-port bytes) under the current control state
    /// — `Processor::cost_of` with the plan always installed.
    fn cost_of(&self, insn: &Insn) -> (Fu, u64, u64) {
        let bw = self.cfg.mem_bw_bytes_per_cycle as u64;
        let lat = self.cfg.mem_latency as u64;
        match *insn {
            Insn::Addi { .. } | Insn::Vsetvli { .. } | Insn::Vsacfg { .. }
            | Insn::VsacfgDim { .. } => (Fu::Scalar, 1, 0),
            Insn::Vle { eew, .. } => {
                let bytes = self.ctrl.vl as u64 * (eew as u64 / 8);
                (Fu::Vldu, lat + bytes.div_ceil(bw).max(1), bytes)
            }
            Insn::Vsald { width, .. } => {
                let prec = match width {
                    WidthSel::FromCfg => self.ctrl.prec,
                    WidthSel::Explicit(p) => p,
                };
                let bytes = prec.bytes_for(self.ctrl.vl as u64);
                (Fu::Vldu, lat + bytes.div_ceil(bw).max(1), bytes)
            }
            Insn::Vse { rs1, .. } => {
                let addr = self.xreg(rs1) as u64;
                let bytes = if !self.plan.is_partial_addr(addr) {
                    self.plan.desc.output_row_elems() * 4
                } else {
                    self.ctrl.vl as u64 * (self.ctrl.sew as u64 / 8)
                };
                (Fu::Vsu, bytes.div_ceil(bw).max(1), bytes)
            }
            Insn::Vmacc { .. }
            | Insn::Vmul { .. }
            | Insn::Vadd { .. }
            | Insn::Vsub { .. }
            | Insn::Vmax { .. }
            | Insn::Vmin { .. }
            | Insn::Vsra { .. } => {
                let per_cycle = self.cfg.lanes as u64 * (64 / self.ctrl.sew as u64).max(1);
                (Fu::Valu, 2 + (self.ctrl.vl as u64).div_ceil(per_cycle), 0)
            }
            Insn::Vmv { .. } => (Fu::Valu, 1, 0),
            Insn::Vsam { stages, .. } | Insn::Vsac { stages, .. } => {
                (Fu::Mptu, PIPE_FILL + stages as u64, 0)
            }
        }
    }

    /// The timing-visible architectural effects of one instruction:
    /// scalar registers, control latching, traffic accounting, the MPTU
    /// stage cursor. VRF bytes and MAC numerics are deliberately absent —
    /// they never feed back into the scoreboard.
    fn effects(&mut self, insn: &Insn, st: &mut SimStats) {
        match *insn {
            Insn::Addi { rd, rs1, imm } => {
                if rd != 0 {
                    self.xregs[rd as usize] = self.xreg(rs1) + imm as i64;
                }
            }
            Insn::Vsetvli { .. } | Insn::Vsacfg { .. } | Insn::VsacfgDim { .. } => {
                let regs = self.xregs;
                self.ctrl.apply(insn, |r| if r == 0 { 0 } else { regs[r as usize] });
            }
            Insn::Vle { rs1, eew, .. } => {
                let addr = self.xreg(rs1) as u64;
                let total = self.ctrl.vl as u64 * (eew as u64 / 8);
                let class = self.classify_load(addr);
                self.traffic.add_read(class, total);
            }
            Insn::Vsald { rs1, width, .. } => {
                let prec = match width {
                    WidthSel::FromCfg => self.ctrl.prec,
                    WidthSel::Explicit(p) => p,
                };
                let addr = self.xreg(rs1) as u64;
                let total = prec.bytes_for(self.ctrl.vl as u64);
                let class = self.classify_load(addr);
                self.traffic.add_read(class, total);
            }
            Insn::Vse { rs1, .. } => {
                let addr = self.xreg(rs1) as u64;
                if self.plan.is_partial_addr(addr) {
                    let bytes = (self.ctrl.vl as u64 * 4).max(4);
                    self.traffic.add_write(TrafficClass::Partial, bytes);
                } else {
                    let bytes = self.plan.desc.output_row_elems() * 4;
                    self.traffic.add_write(TrafficClass::Output, bytes);
                }
            }
            Insn::Vsam { stages, .. } | Insn::Vsac { stages, .. } => {
                let slots = self.cfg.peak_macs_per_cycle(self.plan.desc.prec);
                st.mac_slots += stages as u64 * slots;
                let total = self.plan.total_stages.max(1);
                let before = (self.plan.desc.total_macs() as u128 * self.stage_cursor as u128
                    / total as u128) as u64;
                self.stage_cursor = (self.stage_cursor + stages as u64).min(total);
                let after = (self.plan.desc.total_macs() as u128 * self.stage_cursor as u128
                    / total as u128) as u64;
                st.macs += after - before;
            }
            // Vector-ALU results live only in the VRF: no timing effect.
            Insn::Vmv { .. }
            | Insn::Vadd { .. }
            | Insn::Vsub { .. }
            | Insn::Vmul { .. }
            | Insn::Vmax { .. }
            | Insn::Vmin { .. }
            | Insn::Vsra { .. }
            | Insn::Vmacc { .. } => {}
        }
    }

    fn classify_load(&self, addr: u64) -> TrafficClass {
        let p = &self.plan;
        if p.is_partial_addr(addr) {
            TrafficClass::Partial
        } else if addr >= p.w_addr && p.w_addr > p.in_addr {
            TrafficClass::Weight
        } else {
            // Inside the input region, or an unplaced address: inputs —
            // the same default the simulator uses.
            TrafficClass::Input
        }
    }
}

/// Statically predict the full execution profile of `op` compiled under
/// `choice` — without constructing a processor or touching memory.
///
/// The prediction is exact: it equals the `SimStats` and
/// [`CycleBreakdown`] a quiesced engine reports for the same program
/// (either exec mode — they agree by the parity contract).
pub fn cost_op(
    op: &OpDesc,
    cfg: &SpeedConfig,
    choice: MappingChoice,
) -> Result<StaticCost, SpeedError> {
    op.validate()?;
    let (layout, _) = MemLayout::place(op);
    let summary = compiler::summarize_op_with(op, cfg, choice, &layout)?;
    let plan = OpPlan {
        desc: *op,
        strat: choice.strat,
        in_addr: layout.in_addr,
        w_addr: layout.w_addr,
        out_addr: layout.out_addr,
        partial_addr: layout.partial_addr,
        total_stages: summary.total_stages.max(1),
        functional: false,
    };
    let mut model = CostModel::new(*cfg, plan);
    compiler::stream_op_with(op, cfg, choice, &layout, &mut |seg| {
        model.run_segment(&seg.insns);
        Ok(())
    })?;
    let mut cost = model.finish();
    // Geometric residency flag: `summarize_op_with` already proved the
    // strategy applicable, so `map_op` cannot panic here.
    cost.partials_spilled = !crate::dataflow::map_op(op, cfg, choice.strat).partials_in_vrf;
    Ok(cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::engine::Engine;
    use crate::isa::StrategyKind;

    fn predicted_vs_simulated(op: &OpDesc, choice: MappingChoice) {
        let cfg = SpeedConfig::builder().lanes(4).tile(2, 2).build().unwrap();
        let predicted = cost_op(op, &cfg, choice).unwrap();
        let mut engine = Engine::new(cfg).unwrap();
        let (stats, _) = engine.run_op_with(op, choice, false).unwrap();
        assert_eq!(predicted.stats, stats, "{op:?} {choice:?}");
        assert_eq!(predicted.breakdown, engine.breakdown(), "{op:?} {choice:?}");
        assert_eq!(predicted.breakdown.total(), predicted.stats.cycles);
    }

    #[test]
    fn static_cost_matches_simulation_across_kinds() {
        let cases = [
            (OpDesc::mm(12, 48, 10, Precision::Int8), StrategyKind::Mm),
            (OpDesc::pwcv(16, 16, 8, 8, Precision::Int4), StrategyKind::Cf),
            (OpDesc::dwcv(8, 9, 9, 3, 2, 1, Precision::Int8), StrategyKind::Ff),
            (OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int16), StrategyKind::Ffcs),
        ];
        for (op, strat) in cases {
            predicted_vs_simulated(&op, MappingChoice::of(strat));
        }
    }

    #[test]
    fn static_cost_matches_simulation_on_spilled_schedule() {
        // Large FFCS conv: forces partial-sum spill/reload traffic, the
        // hardest path (partial-region stores cost differently).
        let op = OpDesc::conv(8, 64, 40, 40, 3, 1, 1, Precision::Int8);
        predicted_vs_simulated(&op, MappingChoice::of(StrategyKind::Ffcs));
    }

    #[test]
    fn cost_tuple_orders_by_cycles_then_traffic() {
        let a = StaticCost {
            stats: SimStats { cycles: 10, ..Default::default() },
            breakdown: CycleBreakdown::default(),
            partials_spilled: false,
        };
        assert_eq!(a.cost(), (10, 0));
    }

    #[test]
    fn static_cost_matches_simulation_on_spilled_ff_boundary() {
        // The F=604/608 INT8 residency boundary: the resident side keeps
        // the one-fetch FF stream, the spilled side emits real per-row
        // weight refetches — the static model must stay bit-identical to
        // the simulator on both, and the partial-residency flag reflects
        // the mapping geometry.
        for f in [604u32, 608] {
            let op = OpDesc::conv(8, f, 6, 6, 3, 1, 1, Precision::Int8);
            predicted_vs_simulated(&op, MappingChoice::of(StrategyKind::Ff));
        }
        let cfg = SpeedConfig::reference();
        let big = OpDesc::conv(8, 64, 40, 40, 3, 1, 1, Precision::Int8);
        let spilled = cost_op(&big, &cfg, MappingChoice::of(StrategyKind::Ffcs)).unwrap();
        assert!(spilled.partials_spilled);
        let small = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
        let resident = cost_op(&small, &cfg, MappingChoice::of(StrategyKind::Ffcs)).unwrap();
        assert!(!resident.partials_spilled);
    }
}
