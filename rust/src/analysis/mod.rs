//! Static analysis of compiled SPEED instruction streams: verification,
//! exact cost prediction, and lints.
//!
//! Three passes share this module, all abstract interpreters over
//! [`CompiledOp`] segments that never touch the simulator:
//!
//! * **[`verify`](crate::analysis::verify_segments)** (`V-*` rules, in
//!   [`Rule`]) proves streams *legal* — configuration, dataflow,
//!   memory-safety, fast-path, and residency invariants. Violations are
//!   **errors**: a dirty [`VerifyReport`] folds into
//!   [`SpeedError::Verify`](crate::error::SpeedError::Verify) and the
//!   program never runs.
//! * **[`cost`]** proves what a legal stream *costs*: replaying the
//!   scoreboard's monotone frontier recurrence yields a
//!   [`SimStats`](crate::sim::SimStats) and
//!   [`CycleBreakdown`](crate::obs::CycleBreakdown) bit-identical to
//!   simulating the program — the auto-tuner uses it to rank candidates
//!   without paying for their simulations.
//! * **[`lint`]** (`L-*` rules, in [`lint::LintRule`]) flags streams that
//!   are legal but *wasteful* — dead defs, redundant reloads and config
//!   re-latches, split batch runs, register pressure. Findings are
//!   **warnings**: a dirty [`lint::LintReport`] is advice and never stops
//!   execution.
//!
//! The severity contract is deliberate: anything that could make results
//! wrong is a `V-*` error; anything that only makes them slow is an `L-*`
//! warning. Both report types carry stable rule IDs, per-rule counts, and
//! `(segment, index)` locations so CI can grep them (`repro verify`,
//! `repro lint`).
//!
//! [`CompiledOp`]: crate::compiler::CompiledOp

pub mod cost;
pub mod lint;
mod verify;

pub use verify::{
    ensure_verified, verify_op, verify_segments, Diagnostic, Rule, Verifier, VerifyReport,
    MAX_DIAGNOSTICS,
};
