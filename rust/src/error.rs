//! Typed errors for every fallible path in the crate.
//!
//! Callers match on the failure class instead of parsing strings: a serving
//! loop retries a [`SpeedError::Artifact`] (missing/corrupt AOT outputs),
//! rejects a [`SpeedError::Config`] at admission time, and treats
//! [`SpeedError::Sim`] as a compiler bug (the operator compiler emitted a
//! stream the hardware could not execute). Hand-rolled in the `thiserror`
//! style — the deployment image vendors no proc-macro crates.

use crate::sim::SimError;

/// Crate-wide result alias; the error defaults to [`SpeedError`].
pub type Result<T, E = SpeedError> = std::result::Result<T, E>;

/// Every way a SPEED API can fail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpeedError {
    /// Invalid hardware configuration or request parameter.
    Config(String),
    /// Operator compilation failure: malformed operator descriptor or a
    /// dataflow strategy that does not apply to the operator kind.
    Compile(String),
    /// DRAM placement failure: the operator's tensors do not fit the
    /// configured external memory.
    Layout(String),
    /// The cycle simulator rejected an instruction stream (structural
    /// violation — carries the simulator's own error as `source`).
    Sim(SimError),
    /// AOT-artifact problem: missing/corrupt manifest, golden vectors, or
    /// a PJRT compile/execute failure.
    Artifact(String),
    /// Text parsing failure (assembly source, JSON documents).
    Parse(String),
    /// Benchmark-harness failure: unreadable baseline, or a measured
    /// metric regressed past the gate (`speed-bench --baseline`).
    Bench(String),
    /// Serving-subsystem failure: request queue overflow under
    /// backpressure, submission to a shut-down pool, or a worker that
    /// died while holding a request.
    Serve(String),
    /// Static verification failure: the compiled instruction stream
    /// violates a verifier rule ([`crate::analysis`]) — the program would
    /// misconfigure the hardware, access memory outside its layout, or
    /// break a fast-path precondition if it ever reached the simulator.
    Verify(String),
    /// Observability failure: a profile/trace invariant did not hold
    /// (span durations not summing to the simulated cycle count, a
    /// malformed trace request) or a trace artifact could not be written.
    Obs(String),
}

impl SpeedError {
    /// Stable, matchable class name (also the `Display` prefix).
    pub fn kind(&self) -> &'static str {
        match self {
            SpeedError::Config(_) => "config",
            SpeedError::Compile(_) => "compile",
            SpeedError::Layout(_) => "layout",
            SpeedError::Sim(_) => "sim",
            SpeedError::Artifact(_) => "artifact",
            SpeedError::Parse(_) => "parse",
            SpeedError::Bench(_) => "bench",
            SpeedError::Serve(_) => "serve",
            SpeedError::Verify(_) => "verify",
            SpeedError::Obs(_) => "obs",
        }
    }

    /// The human-readable detail without the class prefix.
    pub fn detail(&self) -> String {
        match self {
            SpeedError::Config(m)
            | SpeedError::Compile(m)
            | SpeedError::Layout(m)
            | SpeedError::Artifact(m)
            | SpeedError::Parse(m)
            | SpeedError::Bench(m)
            | SpeedError::Serve(m)
            | SpeedError::Verify(m)
            | SpeedError::Obs(m) => m.clone(),
            SpeedError::Sim(e) => e.to_string(),
        }
    }
}

impl std::fmt::Display for SpeedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} error: {}", self.kind(), self.detail())
    }
}

impl std::error::Error for SpeedError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpeedError::Sim(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SimError> for SpeedError {
    fn from(e: SimError) -> Self {
        SpeedError::Sim(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::error::Error;

    #[test]
    fn display_carries_kind_and_detail() {
        let e = SpeedError::Config("lanes must be a power of two".into());
        assert_eq!(e.kind(), "config");
        assert_eq!(e.to_string(), "config error: lanes must be a power of two");
        let e = SpeedError::Layout("needs 4096 B, have 256".into());
        assert!(e.to_string().starts_with("layout error: "));
        assert!(e.to_string().contains("4096"));
    }

    #[test]
    fn sim_errors_roundtrip_through_source() {
        let sim = SimError::NoPlan;
        let e: SpeedError = sim.clone().into();
        assert_eq!(e.kind(), "sim");
        // The original simulator error is recoverable via `source()`.
        let src = e.source().expect("sim errors carry a source");
        assert_eq!(src.to_string(), sim.to_string());
        let down = src.downcast_ref::<SimError>().expect("downcast");
        assert_eq!(*down, SimError::NoPlan);
    }

    #[test]
    fn non_sim_errors_have_no_source() {
        for e in [
            SpeedError::Config("x".into()),
            SpeedError::Compile("x".into()),
            SpeedError::Layout("x".into()),
            SpeedError::Artifact("x".into()),
            SpeedError::Parse("x".into()),
            SpeedError::Bench("x".into()),
            SpeedError::Serve("x".into()),
            SpeedError::Verify("x".into()),
            SpeedError::Obs("x".into()),
        ] {
            assert!(e.source().is_none(), "{e}");
        }
    }

    #[test]
    fn every_kind_displays_distinctly() {
        let kinds: Vec<&str> = [
            SpeedError::Config("m".into()),
            SpeedError::Compile("m".into()),
            SpeedError::Layout("m".into()),
            SpeedError::Sim(SimError::StoreUnderflow),
            SpeedError::Artifact("m".into()),
            SpeedError::Parse("m".into()),
            SpeedError::Bench("m".into()),
            SpeedError::Serve("m".into()),
            SpeedError::Verify("m".into()),
            SpeedError::Obs("m".into()),
        ]
        .iter()
        .map(|e| e.kind())
        .collect();
        let mut dedup = kinds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), kinds.len());
    }
}
