//! Bench: regenerates Fig. 10 (external-memory access size per dataflow
//! strategy vs Ara) and times the byte-accurate traffic simulation.

use std::time::Instant;

use speed_rvv::config::SpeedConfig;
use speed_rvv::report::fig10::{fig10, fig10_data};

fn main() {
    let cfg = SpeedConfig::reference();
    println!("=== Fig. 10 — external memory access size ===\n");
    println!("{}", fig10(&cfg));

    let t0 = Instant::now();
    let reps = 10;
    for _ in 0..reps {
        let cells = fig10_data(&cfg);
        assert_eq!(cells.len(), 10);
        std::hint::black_box(cells);
    }
    println!(
        "bench fig10_traffic_sim: {:.1} ms/iter ({reps} reps)",
        t0.elapsed().as_secs_f64() / reps as f64 * 1e3
    );
}
