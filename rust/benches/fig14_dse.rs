//! Bench: regenerates Fig. 14 (27-point design-space exploration) plus the
//! Table II / Fig. 13 / Table III analytical-model reports.

use std::time::Instant;

use speed_rvv::report::{fig13, fig14, table2, table3};

fn main() {
    println!("=== Table II — synthesis comparison ===\n{}", table2());
    println!("=== Fig. 13 — area breakdown ===\n{}", fig13());
    println!("=== Table III — state-of-the-art comparison ===\n{}", table3());

    println!("=== Fig. 14 — design-space exploration ===\n");
    let t0 = Instant::now();
    let (text, points) = fig14();
    println!("{text}");
    println!(
        "bench fig14_dse_sweep: {:.1} s for {} configurations",
        t0.elapsed().as_secs_f64(),
        points.len()
    );
}
