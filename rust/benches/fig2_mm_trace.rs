//! Bench: regenerates Fig. 2 (INT16 MM instruction traces, SPEED vs Ara)
//! and times the simulation of the SPEED trace.
//!
//! (The deployment image vendors no criterion; benches use a hand-rolled
//! measure-and-report harness with warmup + repetitions.)

use std::time::Instant;

use speed_rvv::report::fig2::{fig2, fig2_data};

fn bench<F: FnMut()>(name: &str, mut f: F) {
    for _ in 0..3 {
        f(); // warmup
    }
    let reps = 50;
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!("bench {name}: {:.3} ms/iter ({reps} reps)", per * 1e3);
}

fn main() {
    println!("=== Fig. 2 — INT16 MM instruction-trace comparison ===\n");
    println!("{}", fig2());
    bench("fig2_trace_sim", || {
        let d = fig2_data();
        assert!(d.speed_insns < d.ara_insns);
        std::hint::black_box(d);
    });
}
