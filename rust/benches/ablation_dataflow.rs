//! Ablation: the mixed dataflow mapping vs forcing one strategy everywhere
//! — the design choice Sec. III motivates ("a one-size-fits-all dataflow
//! approach would suffer from under-utilized computation").
//!
//! For every benchmark network (quick scale) and each fixed strategy, the
//! fixed policy runs only the operators the strategy supports; the mixed
//! row is restricted to the same operator subset so the comparison is
//! apples-to-apples. Also reports the traffic arm of the trade-off.

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::{run_model, Policy};
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::zoo::{model_by_name, MODELS};
use speed_rvv::models::OpKind;
use speed_rvv::report::fig12::downscale;

fn main() {
    let cfg = SpeedConfig::reference();
    println!(
        "=== ablation: mixed dataflow vs fixed strategies (INT8, 1/4 scale) ===\n"
    );
    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>14}",
        "model", "mixed cycles", "all-FFCS", "all-CF", "all-FF"
    );
    for name in MODELS {
        let model = downscale(&model_by_name(name).unwrap(), 4);
        let mixed = run_model(&model, Precision::Int8, &cfg, Policy::Mixed).unwrap();
        let mut row = format!("{name:<12}");
        // Mixed total over conv-family ops only (what fixed policies run).
        let conv_subset = |r: &speed_rvv::coordinator::ModelResult, s: StrategyKind| {
            r.layers
                .iter()
                .filter(|l| match s {
                    StrategyKind::Ff => {
                        matches!(l.op.kind, OpKind::Conv | OpKind::Pwcv | OpKind::Dwcv)
                    }
                    _ => matches!(l.op.kind, OpKind::Conv | OpKind::Pwcv),
                })
                .map(|l| l.stats.cycles)
                .sum::<u64>()
        };
        row.push_str(&format!("{:>14}", mixed.vector_cycles()));
        for strat in [StrategyKind::Ffcs, StrategyKind::Cf, StrategyKind::Ff] {
            let fixed =
                run_model(&model, Precision::Int8, &cfg, Policy::Fixed(strat)).unwrap();
            let fixed_cycles: u64 = fixed.layers.iter().map(|l| l.stats.cycles).sum();
            let mixed_same = conv_subset(&mixed, strat);
            let ratio = if mixed_same > 0 {
                fixed_cycles as f64 / mixed_same as f64
            } else {
                f64::NAN
            };
            row.push_str(&format!("{:>13.2}x", ratio));
        }
        println!("{row}");
    }
    println!(
        "\n(cells are fixed-policy cycles / mixed-policy cycles on the same \
         operator subset; > 1.00x means the mixed mapping wins)\n"
    );

    // The traffic arm of the trade-off, on MobileNetV2.
    let model = downscale(&model_by_name("mobilenetv2").unwrap(), 4);
    println!("MobileNetV2 traffic by policy (INT8):");
    for (label, policy) in [
        ("mixed", Policy::Mixed),
        ("all-FFCS", Policy::Fixed(StrategyKind::Ffcs)),
        ("all-CF", Policy::Fixed(StrategyKind::Cf)),
        ("all-FF", Policy::Fixed(StrategyKind::Ff)),
    ] {
        let r = run_model(&model, Precision::Int8, &cfg, policy).unwrap();
        println!(
            "  {label:<9} {:8.2} MiB DRAM over {:2} layers ({} cycles)",
            r.total.traffic.total() as f64 / (1 << 20) as f64,
            r.layers.len(),
            r.vector_cycles()
        );
    }
}
