//! Bench: regenerates Fig. 11 (operator performance vs Ara across tensor
//! sizes) and times the operator-level sweep.

use std::time::Instant;

use speed_rvv::config::SpeedConfig;
use speed_rvv::report::fig11::{fig11, fig11_data, DEFAULT_SIZES};

fn main() {
    let cfg = SpeedConfig::reference();
    println!("=== Fig. 11 — operator performance across tensor sizes ===\n");
    println!("{}", fig11(&cfg, &DEFAULT_SIZES));

    let t0 = Instant::now();
    let reps = 5;
    for _ in 0..reps {
        let pts = fig11_data(&cfg, &[8, 16]);
        std::hint::black_box(pts);
    }
    println!(
        "bench fig11_operator_sweep: {:.1} ms/iter ({reps} reps)",
        t0.elapsed().as_secs_f64() / reps as f64 * 1e3
    );
}
