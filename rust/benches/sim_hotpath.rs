//! Bench: the simulator hot path itself (the L3 performance deliverable).
//!
//! Measures simulated-stages-per-second on a large CONV3×3 stream — the
//! metric the EXPERIMENTS.md §Perf log tracks — plus instruction-stream
//! generation throughput and the PJRT execute path when artifacts exist.

use std::time::Instant;

use speed_rvv::compiler::{execute_op, summarize_op, MemLayout};
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::ops::OpDesc;
use speed_rvv::sim::Processor;

fn main() {
    let cfg = SpeedConfig::reference();
    let op = OpDesc::conv(64, 64, 56, 56, 3, 1, 1, Precision::Int16);
    let layout = MemLayout::for_op(&op, 1 << 26).unwrap();

    // --- instruction-stream generation only (codegen throughput) --------
    let t0 = Instant::now();
    let reps = 5;
    let mut summary = None;
    for _ in 0..reps {
        summary = Some(summarize_op(&op, &cfg, StrategyKind::Ffcs, &layout).unwrap());
    }
    let s = summary.unwrap();
    let gen_per = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "codegen: {:.1} ms for {} insns ({:.1} M insns/s)",
        gen_per * 1e3,
        s.total_insns,
        s.total_insns as f64 / gen_per / 1e6
    );

    // --- full simulation (codegen + scoreboard + traffic) ---------------
    let t0 = Instant::now();
    let mut stats = None;
    for _ in 0..reps {
        let mut p = Processor::new(cfg, 1 << 26);
        let (st, _) = execute_op(&mut p, &op, StrategyKind::Ffcs, layout, false).unwrap();
        stats = Some(st);
    }
    let st = stats.unwrap();
    let sim_per = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "simulate: {:.1} ms for {} cycles / {} stages ({:.1} M insns/s, {:.1} M simcycles/s)",
        sim_per * 1e3,
        st.cycles,
        s.total_stages,
        s.total_insns as f64 / sim_per / 1e6,
        st.cycles as f64 / sim_per / 1e6
    );

    // --- PJRT execute hot path (if artifacts built) ----------------------
    if let Ok(mut engine) = speed_rvv::runtime::Engine::open("artifacts") {
        let a: Vec<i32> = vec![1; 32 * 64];
        let b: Vec<i32> = vec![1; 64 * 32];
        let _ = engine.execute("mm_i8", &[a.clone(), b.clone()]).unwrap(); // warm
        let t0 = Instant::now();
        let reps = 200;
        for _ in 0..reps {
            let out = engine.execute("mm_i8", &[a.clone(), b.clone()]).unwrap();
            std::hint::black_box(out);
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("pjrt_execute mm_i8: {:.2} ms/call ({reps} reps)", per * 1e3);
    }
}
