//! Bench: the simulator hot path itself (the L3 performance deliverable).
//!
//! Measures simulated-stages-per-second on a large CONV3×3 stream — the
//! metric `speed-bench` records into `BENCH_sim.json` — in both execution
//! modes (exact per-instruction stepping vs the stream-run batch fast
//! path), plus instruction-stream generation throughput and the PJRT
//! execute path when artifacts exist.

use std::time::Instant;

use speed_rvv::bench::{hotpath_op, measure_hotpath};
use speed_rvv::compiler::{summarize_op, MemLayout};
use speed_rvv::config::SpeedConfig;
use speed_rvv::isa::StrategyKind;
use speed_rvv::sim::ExecMode;

fn main() {
    let cfg = SpeedConfig::reference();
    let op = hotpath_op(false);
    let layout = MemLayout::for_op(&op, 1 << 26).unwrap();

    // --- instruction-stream generation only (codegen throughput) --------
    let t0 = Instant::now();
    let reps = 5;
    let mut summary = None;
    for _ in 0..reps {
        summary = Some(summarize_op(&op, &cfg, StrategyKind::Ffcs, &layout).unwrap());
    }
    let s = summary.unwrap();
    let gen_per = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "codegen: {:.1} ms for {} insns ({:.1} M insns/s)",
        gen_per * 1e3,
        s.total_insns,
        s.total_insns as f64 / gen_per / 1e6
    );

    // --- full simulation, both execution modes ---------------------------
    for (label, mode) in [("exact", ExecMode::Exact), ("batch", ExecMode::Batch)] {
        let (wall, stages) = measure_hotpath(&op, mode, 3).unwrap();
        println!(
            "simulate[{label}]: {:.1} ms for {} stages ({:.2} M stages/s, {:.1} M insns/s)",
            wall * 1e3,
            stages,
            stages as f64 / wall / 1e6,
            s.total_insns as f64 / wall / 1e6
        );
    }

    // --- PJRT execute hot path (if artifacts built) ----------------------
    if let Ok(mut engine) = speed_rvv::runtime::PjrtEngine::open("artifacts") {
        let a: Vec<i32> = vec![1; 32 * 64];
        let b: Vec<i32> = vec![1; 64 * 32];
        let _ = engine.execute("mm_i8", &[a.clone(), b.clone()]).unwrap(); // warm
        let t0 = Instant::now();
        let reps = 200;
        for _ in 0..reps {
            let out = engine.execute("mm_i8", &[a.clone(), b.clone()]).unwrap();
            std::hint::black_box(out);
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("pjrt_execute mm_i8: {:.2} ms/call ({reps} reps)", per * 1e3);
    }
}
