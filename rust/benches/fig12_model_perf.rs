//! Bench: regenerates Fig. 12 (model-level SPEED vs Ara on the six DNN
//! benchmarks at 16/8/4-bit).
//!
//! Pass `--full` for the full-size networks (≈20 s of simulation across
//! all 18 points); the default quick mode uses 1/4-scale feature maps.

use std::time::Instant;

use speed_rvv::config::SpeedConfig;
use speed_rvv::report::fig12::fig12;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = SpeedConfig::reference();
    println!("=== Fig. 12 — model-level performance ===\n");
    let t0 = Instant::now();
    println!("{}", fig12(&cfg, !full));
    println!(
        "bench fig12_model_suite{}: {:.1} s total (6 models x 3 precisions)",
        if full { " (full)" } else { " (quick)" },
        t0.elapsed().as_secs_f64()
    );
}
