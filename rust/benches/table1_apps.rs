//! Bench: regenerates Table I (complete-application VGG16 + MobileNetV2
//! inference at INT8, conv-only and complete, vs Ara).
//!
//! Pass `--full` for the full 224×224 networks.

use std::time::Instant;

use speed_rvv::config::SpeedConfig;
use speed_rvv::report::table1::table1;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = SpeedConfig::reference();
    println!("=== Table I — complete-application inference ===\n");
    let t0 = Instant::now();
    println!("{}", table1(&cfg, !full));
    println!(
        "bench table1_apps{}: {:.1} s total",
        if full { " (full)" } else { " (quick)" },
        t0.elapsed().as_secs_f64()
    );
}
