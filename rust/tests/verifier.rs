//! Mutation harness for the static stream verifier (`speed_rvv::analysis`).
//!
//! Each test takes a genuine compiler-emitted program, breaks exactly one
//! invariant the way a codegen bug would (drop a `VSACFG`, swap a vector
//! register, shift an address past its partition, corrupt run metadata),
//! and asserts that the verifier fires the *intended* rule ID. Collateral
//! diagnostics are allowed — a broken stream may violate several
//! invariants at once — but the targeted rule must be among them.
//!
//! The final property test is the other half of the contract: across
//! seeded random operators, precisions, and feasible mapping candidates,
//! every unmutated codegen stream must be verifier-clean (no false
//! positives).

use speed_rvv::analysis::{verify_op, verify_segments, Rule, VerifyReport};
use speed_rvv::compiler::{compile_op_with, MemLayout};
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::dataflow::{self, MappingChoice};
use speed_rvv::isa::{
    Dim, Insn, LdMode, RunKind, Segment, StrategyKind, StreamRun, Vtype, WidthSel,
};
use speed_rvv::models::OpDesc;

fn cfg() -> SpeedConfig {
    SpeedConfig::reference()
}

/// Compile `op` under `strat` and hand back everything a mutation needs.
fn compile(op: &OpDesc, strat: StrategyKind) -> (MappingChoice, MemLayout, Vec<Segment>) {
    let choice = MappingChoice::of(strat);
    let (layout, _) = MemLayout::place(op);
    let segs = compile_op_with(op, &cfg(), choice, layout, false)
        .expect("fixture op compiles")
        .segments;
    (choice, layout, segs)
}

fn verify(
    op: &OpDesc,
    choice: MappingChoice,
    layout: MemLayout,
    segs: &[Segment],
) -> VerifyReport {
    verify_segments(op, &cfg(), choice, layout, segs)
}

/// First `(segment, index)` whose instruction matches `pred`.
fn find_pos(segs: &[Segment], pred: impl Fn(&Insn) -> bool) -> (usize, usize) {
    for (s, seg) in segs.iter().enumerate() {
        if let Some(i) = seg.insns.iter().position(&pred) {
            return (s, i);
        }
    }
    panic!("instruction pattern not found in stream");
}

/// First `(segment, index-of-Addi)` of a `(li ; vsald)` pair whose address
/// falls in `[lo, hi)`.
fn find_load_pair(segs: &[Segment], lo: u64, hi: u64) -> (usize, usize) {
    for (s, seg) in segs.iter().enumerate() {
        let hit = seg.insns.windows(2).position(|p| match (p[0], p[1]) {
            (Insn::Addi { rd, rs1: 0, imm }, Insn::Vsald { rs1, .. }) => {
                rd != 0 && rs1 == rd && imm >= 0 && (imm as u64) >= lo && (imm as u64) < hi
            }
            _ => false,
        });
        if let Some(i) = hit {
            return (s, i);
        }
    }
    panic!("no load pair addressed in [{lo:#x}, {hi:#x})");
}

/// First `(segment, index-of-Addi)` of a `(li ; vse)` pair whose address
/// falls in `[lo, hi)`.
fn find_store_pair(segs: &[Segment], lo: u64, hi: u64) -> (usize, usize) {
    for (s, seg) in segs.iter().enumerate() {
        let hit = seg.insns.windows(2).position(|p| match (p[0], p[1]) {
            (Insn::Addi { rd, rs1: 0, imm }, Insn::Vse { rs1, .. }) => {
                rd != 0 && rs1 == rd && imm >= 0 && (imm as u64) >= lo && (imm as u64) < hi
            }
            _ => false,
        });
        if let Some(i) = hit {
            return (s, i);
        }
    }
    panic!("no store pair addressed in [{lo:#x}, {hi:#x})");
}

/// First run of `kind` in the stream as `(segment, run-index)`.
fn find_run(segs: &[Segment], kind: RunKind) -> (usize, usize) {
    for (s, seg) in segs.iter().enumerate() {
        if let Some(r) = seg.runs.iter().position(|r| r.kind == kind) {
            return (s, r);
        }
    }
    panic!("no {kind:?} run in stream");
}

fn mm_fixture() -> (OpDesc, MappingChoice, MemLayout, Vec<Segment>) {
    let op = OpDesc::mm(8, 16, 8, Precision::Int8);
    let (choice, layout, segs) = compile(&op, StrategyKind::Mm);
    (op, choice, layout, segs)
}

fn ff_fixture() -> (OpDesc, MappingChoice, MemLayout, Vec<Segment>) {
    let op = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
    let (choice, layout, segs) = compile(&op, StrategyKind::Ff);
    (op, choice, layout, segs)
}

// ---------------------------------------------------------------- V-CFG --

#[test]
fn dropped_vsacfg_fires_v_cfg_01() {
    let (op, choice, layout, mut segs) = ff_fixture();
    let (s, i) = find_pos(&segs, |x| matches!(x, Insn::Vsacfg { .. }));
    segs[s].insns[i] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::CfgNotLatched), "{:?}", r.diagnostics.first());
}

#[test]
fn swapped_precision_fires_v_cfg_02() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, i) = find_pos(&segs, |x| matches!(x, Insn::Vsacfg { .. }));
    segs[s].insns[i] = Insn::Vsacfg {
        rd: 25,
        zimm: Insn::pack_cfg(Precision::Int4, 1, StrategyKind::Mm),
        uimm: 0,
    };
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::CfgMismatch), "{:?}", r.diagnostics.first());
    assert!(!r.fired(Rule::CfgNotLatched), "a latch did happen");
}

#[test]
fn dropped_dim_latch_fires_v_cfg_03() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, i) = find_pos(&segs, |x| matches!(x, Insn::VsacfgDim { dim: Dim::K, .. }));
    segs[s].insns[i] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::DimUnset), "{:?}", r.diagnostics.first());
}

#[test]
fn dropped_vsetvli_fires_v_cfg_04() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, i) = find_pos(&segs, |x| matches!(x, Insn::Vsetvli { .. }));
    segs[s].insns[i] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::VlUnset), "{:?}", r.diagnostics.first());
}

#[test]
fn undecodable_zimm_fires_v_cfg_05() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, i) = find_pos(&segs, |x| matches!(x, Insn::Vsacfg { .. }));
    // Precision bits 0b11 decode to no precision at all.
    segs[s].insns[i] = Insn::Vsacfg { rd: 25, zimm: 0x0003, uimm: 0 };
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::CfgEncoding), "{:?}", r.diagnostics.first());
}

// ---------------------------------------------------------------- V-REG --

#[test]
fn tensor_before_any_load_fires_v_reg_01() {
    let op = OpDesc::mm(4, 4, 4, Precision::Int8);
    let choice = MappingChoice::of(StrategyKind::Mm);
    let (layout, _) = MemLayout::place(&op);
    // A hand-built prologue that latches everything correctly, then fires
    // a tensor burst with no VSALD ever staged.
    let seg = Segment::new(vec![
        Insn::Vsacfg {
            rd: 25,
            zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm),
            uimm: 0,
        },
        Insn::Addi { rd: 25, rs1: 0, imm: 4 },
        Insn::VsacfgDim { rd: 0, rs1: 25, dim: Dim::M },
        Insn::Addi { rd: 25, rs1: 0, imm: 4 },
        Insn::VsacfgDim { rd: 0, rs1: 25, dim: Dim::K },
        Insn::Addi { rd: 25, rs1: 0, imm: 4 },
        Insn::VsacfgDim { rd: 0, rs1: 25, dim: Dim::N },
        Insn::Addi { rd: 30, rs1: 0, imm: 4 },
        Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(8) },
        Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 1 },
    ]);
    let r = verify(&op, choice, layout, &[seg]);
    assert!(r.fired(Rule::UseBeforeDef), "{:?}", r.diagnostics.first());
}

#[test]
fn unconsumed_trailing_load_fires_v_reg_02() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let last = segs.len() - 1;
    segs[last].insns.push(Insn::Addi { rd: 29, rs1: 0, imm: layout.in_addr as i32 });
    segs[last].insns.push(Insn::Vsald {
        vd: 2,
        rs1: 29,
        mode: LdMode::Sequential,
        width: WidthSel::FromCfg,
    });
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::DeadLoad), "{:?}", r.diagnostics.first());
}

#[test]
fn swapped_tensor_operand_fires_v_reg_03() {
    let (op, choice, layout, mut segs) = mm_fixture();
    // Uniformly remap every burst's input operand so run homogeneity is
    // preserved but the operand no longer names the freshest load.
    let mut swapped = 0;
    for seg in &mut segs {
        for insn in &mut seg.insns {
            match insn {
                Insn::Vsam { vs1, .. } | Insn::Vsac { vs1, .. } => {
                    *vs1 ^= 1;
                    swapped += 1;
                }
                _ => {}
            }
        }
    }
    assert!(swapped > 0);
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::StaleOperand), "{:?}", r.diagnostics.first());
    assert!(!r.fired(Rule::TensorRunNotHomogeneous), "uniform remap keeps runs homogeneous");
}

// ---------------------------------------------------------------- V-MEM --

#[test]
fn load_shifted_past_partition_fires_v_mem_01() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, i) = find_load_pair(&segs, layout.in_addr, layout.w_addr);
    // Last byte of the input partition: the transfer now runs off its end
    // (while the base address still classifies as an input-region load).
    let shifted = layout.in_addr + op.input_bytes() - 1;
    if let Insn::Addi { rd, .. } = segs[s].insns[i] {
        segs[s].insns[i] = Insn::Addi { rd, rs1: 0, imm: shifted as i32 };
    }
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::LoadOutOfRegion), "{:?}", r.diagnostics.first());
}

#[test]
fn misaligned_output_store_fires_v_mem_02() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, i) = find_store_pair(&segs, layout.out_addr, layout.partial_addr);
    if let Insn::Addi { rd, imm, .. } = segs[s].insns[i] {
        segs[s].insns[i] = Insn::Addi { rd, rs1: 0, imm: imm + 4 };
    }
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::StoreNotRow), "{:?}", r.diagnostics.first());
}

#[test]
fn non_accumulator_partial_spill_fires_v_mem_03() {
    let op = OpDesc::mm(4, 4, 4, Precision::Int8);
    let choice = MappingChoice::of(StrategyKind::Mm);
    let (layout, _) = MemLayout::place(&op);
    // A spill drained at SEW 8: partials are 32-bit accumulators.
    let seg = Segment::new(vec![
        Insn::Addi { rd: 30, rs1: 0, imm: 4 },
        Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(8) },
        Insn::Addi { rd: 27, rs1: 0, imm: layout.partial_addr as i32 },
        Insn::Vse { vs3: 16, rs1: 27, eew: 32 },
    ]);
    let r = verify(&op, choice, layout, &[seg]);
    assert!(r.fired(Rule::PartialOutOfRegion), "{:?}", r.diagnostics.first());
}

#[test]
fn untracked_address_fires_v_mem_04() {
    let op = OpDesc::mm(4, 4, 4, Precision::Int8);
    let choice = MappingChoice::of(StrategyKind::Mm);
    let (layout, _) = MemLayout::place(&op);
    // x22 is never written: the access is not statically provable.
    let seg = Segment::new(vec![
        Insn::Vsacfg {
            rd: 25,
            zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm),
            uimm: 0,
        },
        Insn::Addi { rd: 30, rs1: 0, imm: 4 },
        Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(8) },
        Insn::Vsald { vd: 0, rs1: 22, mode: LdMode::Sequential, width: WidthSel::FromCfg },
    ]);
    let r = verify(&op, choice, layout, &[seg]);
    assert!(r.fired(Rule::UnprovenAccess), "{:?}", r.diagnostics.first());
}

#[test]
fn oversized_broadcast_fires_v_mem_05() {
    let op = OpDesc::mm(4, 4, 4, Precision::Int8);
    let choice = MappingChoice::of(StrategyKind::Mm);
    let (layout, _) = MemLayout::place(&op);
    // 100_000 broadcast bytes cannot fit one vector-register region.
    let seg = Segment::new(vec![
        Insn::Vsacfg {
            rd: 25,
            zimm: Insn::pack_cfg(Precision::Int8, 1, StrategyKind::Mm),
            uimm: 0,
        },
        Insn::Addi { rd: 30, rs1: 0, imm: 100_000 },
        Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(8) },
        Insn::Addi { rd: 29, rs1: 0, imm: layout.in_addr as i32 },
        Insn::Vsald { vd: 0, rs1: 29, mode: LdMode::Broadcast, width: WidthSel::FromCfg },
    ]);
    let r = verify(&op, choice, layout, &[seg]);
    assert!(r.fired(Rule::VrfOverflow), "{:?}", r.diagnostics.first());
}

// ---------------------------------------------------------------- V-RUN --

#[test]
fn out_of_bounds_run_fires_v_run_01() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let last = segs.len() - 1;
    let n = segs[last].insns.len() as u32;
    segs[last].runs.push(StreamRun { start: n, len: 2, kind: RunKind::Load });
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::RunBounds), "{:?}", r.diagnostics.first());
}

#[test]
fn broken_tensor_run_fires_v_run_02() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, ri) = find_run(&segs, RunKind::Tensor);
    let start = segs[s].runs[ri].start as usize;
    // A non-tensor instruction where the run metadata promises a burst.
    segs[s].insns[start] = Insn::Vmv { vd: 8, rs1: 0 };
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::TensorRunNotHomogeneous), "{:?}", r.diagnostics.first());
}

#[test]
fn corrupted_load_pair_fires_v_run_03() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, ri) = find_run(&segs, RunKind::Load);
    let i = segs[s].runs[ri].start as usize + 1;
    if let Insn::Vsald { vd, mode, width, .. } = segs[s].insns[i] {
        // The load no longer reads the address its `li` partner set up.
        segs[s].insns[i] = Insn::Vsald { vd, rs1: 21, mode, width };
    } else {
        panic!("load run does not start with (li ; vsald)");
    }
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::LoadRunPairs), "{:?}", r.diagnostics.first());
}

#[test]
fn corrupted_store_pair_fires_v_run_04() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let (s, ri) = find_run(&segs, RunKind::Store);
    let i = segs[s].runs[ri].start as usize + 1;
    if let Insn::Vse { vs3, eew, .. } = segs[s].insns[i] {
        segs[s].insns[i] = Insn::Vse { vs3, rs1: 21, eew };
    } else {
        panic!("store run does not start with (li ; vse)");
    }
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::StoreRunPairs), "{:?}", r.diagnostics.first());
}

#[test]
fn zero_stage_burst_fires_v_run_05() {
    let (op, choice, layout, mut segs) = mm_fixture();
    let mut zeroed = 0;
    for seg in &mut segs {
        for insn in &mut seg.insns {
            match insn {
                Insn::Vsam { stages, .. } | Insn::Vsac { stages, .. } => {
                    *stages = 0;
                    zeroed += 1;
                }
                _ => {}
            }
        }
    }
    assert!(zeroed > 0);
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::ZeroStageTensor), "{:?}", r.diagnostics.first());
    assert!(!r.fired(Rule::TensorRunNotHomogeneous), "uniform zeroing keeps runs homogeneous");
}

// ---------------------------------------------------------------- V-RES --

#[test]
fn extra_weight_fetch_fires_v_res_01() {
    let (op, choice, layout, mut segs) = ff_fixture();
    // One more weight-region fetch than the tensor holds: an FF stream
    // promised residency, so any refetch is a violation.
    let last = segs.len() - 1;
    segs[last].insns.push(Insn::Addi { rd: 29, rs1: 0, imm: layout.w_addr as i32 });
    segs[last].insns.push(Insn::Vsald {
        vd: 4,
        rs1: 29,
        mode: LdMode::Sequential,
        width: WidthSel::FromCfg,
    });
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::WeightRefetch), "{:?}", r.diagnostics.first());
}

#[test]
fn missing_weight_fetch_fires_v_res_02() {
    let (op, choice, layout, mut segs) = ff_fixture();
    let (s, i) = find_load_pair(&segs, layout.w_addr, layout.out_addr);
    // Erase one weight transfer entirely (run metadata cleared so only
    // the coverage invariant is under test).
    segs[s].insns[i] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
    segs[s].insns[i + 1] = Insn::Addi { rd: 0, rs1: 0, imm: 0 };
    segs[s].runs.clear();
    let r = verify(&op, choice, layout, &segs);
    assert!(r.fired(Rule::WeightCoverage), "{:?}", r.diagnostics.first());
}

// ---------------------------------------------------- no false positives --

/// xorshift64* PRNG (same shape as the other property suites): the tests
/// must be deterministic, so no OS entropy.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u32 {
        (lo + self.next() % (hi - lo + 1)) as u32
    }
}

#[test]
fn every_codegen_stream_is_verifier_clean() {
    let cfg = cfg();
    let precs = [Precision::Int16, Precision::Int8, Precision::Int4];
    let mut rng = Rng::new(0x5EED_CAFE);
    let mut verified = 0u32;
    for trial in 0..40u32 {
        let prec = precs[rng.range(0, 2) as usize];
        let op = match rng.range(0, 3) {
            0 => OpDesc::mm(rng.range(1, 16), rng.range(1, 40), rng.range(1, 16), prec),
            1 => {
                let k = [1u32, 3][rng.range(0, 1) as usize];
                OpDesc::conv(
                    rng.range(1, 10),
                    rng.range(1, 10),
                    rng.range(4, 12),
                    rng.range(4, 12),
                    k,
                    rng.range(1, 2),
                    k / 2,
                    prec,
                )
            }
            2 => OpDesc::pwcv(rng.range(1, 12), rng.range(1, 12), rng.range(2, 10), rng.range(2, 10), prec),
            _ => {
                let k = [1u32, 3][rng.range(0, 1) as usize];
                OpDesc::dwcv(rng.range(1, 12), rng.range(4, 12), rng.range(4, 12), k, rng.range(1, 2), k / 2, prec)
            }
        };
        if op.validate().is_err() {
            continue;
        }
        for strat in StrategyKind::ALL {
            if !dataflow::feasible(strat, &op, &cfg) {
                continue;
            }
            let mut choices = vec![MappingChoice::of(strat)];
            // One non-default chunk per strategy keeps the tuner's
            // candidate space honest without blowing up test time.
            if let Some(c) = dataflow::chunk_candidates(&op, &cfg, strat).first() {
                choices.push(MappingChoice { chunk: Some(*c), ..MappingChoice::of(strat) });
            }
            for choice in choices {
                let report = verify_op(&op, &cfg, choice)
                    .unwrap_or_else(|e| panic!("trial {trial} {op:?} {strat}: {e}"));
                assert!(
                    report.is_clean(),
                    "trial {trial} {op:?} {choice}: {:?}",
                    report.diagnostics.first()
                );
                verified += 1;
            }
        }
    }
    assert!(verified > 40, "property test exercised too few programs ({verified})");
}
