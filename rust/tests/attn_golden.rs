//! Attention golden contract (ISSUE 7 acceptance bar): the
//! FlashAttention-style two-pass tiled evaluation must be bit-exact
//! against the naive scalar reference at every supported precision and
//! for **any** KV tile size — in particular for the VRF-budget tile the
//! MM lowering actually picks, and for the growing-KV shapes autoregressive
//! decode produces.

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::models::attn::{attn_reference, attn_tiled, seeded_operands, AttnDesc};

const PRECS: [Precision; 3] = [Precision::Int4, Precision::Int8, Precision::Int16];

#[test]
fn tiled_attention_is_bit_exact_at_every_precision_and_tile_size() {
    for prec in PRECS {
        for desc in [
            AttnDesc::prefill(2, 8, 12, prec),
            AttnDesc::decode(4, 16, 33, prec),
        ] {
            let (q, k, v) = seeded_operands(&desc, 0xA77E_0001);
            let golden = attn_reference(&desc, &q, &k, &v);
            let out_len = (desc.heads * desc.q_len * desc.head_dim) as usize;
            assert_eq!(golden.len(), out_len, "{desc:?}");
            assert!(golden.iter().any(|&x| x != 0), "degenerate golden: {desc:?}");
            for tile in [1, 2, 3, 5, 8, desc.kv_len - 1, desc.kv_len, desc.kv_len + 7] {
                let tiled = attn_tiled(&desc, &q, &k, &v, tile);
                assert_eq!(tiled, golden, "{prec} tile={tile} {desc:?}");
            }
        }
    }
}

#[test]
fn vrf_budget_tile_is_exact_and_lowering_conserves_macs() {
    let cfg = SpeedConfig::reference();
    for prec in PRECS {
        let desc = AttnDesc::decode(4, 32, 96, prec);
        let tile = desc.kv_tile(&cfg);
        assert!(tile >= 1 && tile <= desc.kv_len, "{prec}: tile={tile}");

        // The tile the lowering actually uses is bit-exact too.
        let (q, k, v) = seeded_operands(&desc, 0xBEEF);
        assert_eq!(
            attn_tiled(&desc, &q, &k, &v, tile),
            attn_reference(&desc, &q, &k, &v),
            "{prec}: vrf tile {tile}"
        );

        // Lowering emits (QK^T, AV) MM pairs that exactly conserve the
        // analytic MAC count, at the operand precision.
        let ops = desc.lower(&cfg);
        assert!(ops.len() >= 2 && ops.len() % 2 == 0, "{prec}: {} ops", ops.len());
        let macs: u64 = ops
            .iter()
            .map(|o| o.m as u64 * o.k as u64 * o.n as u64)
            .sum();
        assert_eq!(macs, desc.total_macs(), "{prec}");
        assert!(ops.iter().all(|o| o.prec == prec), "{prec}");
    }
}

#[test]
fn seeded_operands_respect_the_precision_range() {
    for prec in PRECS {
        let desc = AttnDesc::prefill(3, 4, 7, prec);
        let (q, k, v) = seeded_operands(&desc, 42);
        let (lo, hi) = prec.range();
        for x in q.iter().chain(&k).chain(&v) {
            assert!(*x >= lo && *x <= hi, "{prec}: {x} outside [{lo}, {hi}]");
        }
        // Deterministic: same seed, same operands.
        assert_eq!(seeded_operands(&desc, 42).0, q);
        assert_ne!(seeded_operands(&desc, 43).0, q);
    }
}

#[test]
fn decode_attention_grows_with_the_kv_cache() {
    // The serving shape: one query token over a cache that grows by one
    // entry per step. Every step stays bit-exact under tiling, and the
    // declared residency grows monotonically.
    let mut last_kv = 0;
    for step in 0..6u32 {
        let desc = AttnDesc::decode(2, 8, 17 + step, Precision::Int8);
        let (q, k, v) = seeded_operands(&desc, 7 + step as u64);
        let golden = attn_reference(&desc, &q, &k, &v);
        assert_eq!(attn_tiled(&desc, &q, &k, &v, 4), golden, "step {step}");
        assert!(desc.kv_bytes() > last_kv, "step {step}");
        last_kv = desc.kv_bytes();
    }
}
