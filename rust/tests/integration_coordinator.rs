//! Integration: the coordinator scheduling whole (downscaled) benchmark
//! networks across policies, precisions and configurations.

use speed_rvv::ara::AraParams;
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::runner::run_parallel;
use speed_rvv::coordinator::{run_model, run_model_ara, Policy};
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::zoo::{model_by_name, MODELS};
use speed_rvv::models::OpKind;
use speed_rvv::report::fig12::downscale;

#[test]
fn every_zoo_model_runs_under_mixed_policy() {
    let cfg = SpeedConfig::reference();
    for name in MODELS {
        let model = downscale(&model_by_name(name).unwrap(), 8);
        let r = run_model(&model, Precision::Int8, &cfg, Policy::Mixed)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(r.layers.len(), model.ops.len(), "{name}");
        assert_eq!(
            r.total.macs,
            model.ops.iter().map(|o| o.total_macs()).sum::<u64>(),
            "{name}"
        );
        // Mixed policy used the matched strategy per operator kind.
        for l in &r.layers {
            let want = match l.op.kind {
                OpKind::Mm => StrategyKind::Mm,
                OpKind::Conv => StrategyKind::Ffcs,
                OpKind::Pwcv => StrategyKind::Cf,
                OpKind::Dwcv => StrategyKind::Ff,
            };
            assert_eq!(l.strat, want, "{name} {:?}", l.op.kind);
        }
    }
}

#[test]
fn mixed_policy_beats_or_matches_fixed_policies() {
    // The paper's claim for the mixed dataflow: it leverages the strengths
    // of each strategy. On a PWCV+DWCV-heavy model the mixed policy must
    // not lose to forcing FFCS everywhere it applies.
    let cfg = SpeedConfig::reference();
    let model = downscale(&model_by_name("mobilenetv2").unwrap(), 4);
    let mixed = run_model(&model, Precision::Int8, &cfg, Policy::Mixed).unwrap();
    let ffcs =
        run_model(&model, Precision::Int8, &cfg, Policy::Fixed(StrategyKind::Ffcs)).unwrap();
    // Compare on the layers FFCS can run (PWCV + CONV).
    let mixed_sub: u64 = mixed
        .layers
        .iter()
        .filter(|l| matches!(l.op.kind, OpKind::Pwcv | OpKind::Conv))
        .map(|l| l.stats.cycles)
        .sum();
    let ffcs_sub: u64 = ffcs.layers.iter().map(|l| l.stats.cycles).sum();
    assert!(
        mixed_sub <= ffcs_sub,
        "mixed {mixed_sub} cycles > all-FFCS {ffcs_sub} on its own subset"
    );
}

#[test]
fn speedup_over_ara_holds_for_all_models_and_precisions() {
    let cfg = SpeedConfig::reference();
    let params = AraParams::default();
    for name in MODELS {
        let model = downscale(&model_by_name(name).unwrap(), 8);
        for prec in [Precision::Int16, Precision::Int8, Precision::Int4] {
            let s = run_model(&model, prec, &cfg, Policy::Mixed).unwrap();
            let a = run_model_ara(&model, prec, &params);
            assert!(
                a.cycles > s.vector_cycles(),
                "{name}@{prec}: Ara {} !> SPEED {}",
                a.cycles,
                s.vector_cycles()
            );
        }
    }
}

#[test]
fn bigger_configs_are_not_slower() {
    let model = downscale(&model_by_name("resnet18").unwrap(), 8);
    let small = run_model(&model, Precision::Int8, &SpeedConfig::dse(2, 2, 2), Policy::Mixed)
        .unwrap();
    let big = run_model(&model, Precision::Int8, &SpeedConfig::dse(8, 4, 4), Policy::Mixed)
        .unwrap();
    assert!(
        big.vector_cycles() < small.vector_cycles(),
        "8L4x4 {} !< 2L2x2 {}",
        big.vector_cycles(),
        small.vector_cycles()
    );
}

#[test]
fn parallel_sweep_matches_serial() {
    let cfg = SpeedConfig::reference();
    let model = downscale(&model_by_name("vit_tiny").unwrap(), 8);
    let precs = vec![Precision::Int16, Precision::Int8, Precision::Int4];
    let serial: Vec<u64> = precs
        .iter()
        .map(|&p| run_model(&model, p, &cfg, Policy::Mixed).unwrap().vector_cycles())
        .collect();
    let parallel = run_parallel(precs, 3, |&p| {
        run_model(&model, p, &cfg, Policy::Mixed).unwrap().vector_cycles()
    });
    assert_eq!(serial, parallel, "simulation must be deterministic");
}

#[test]
fn scalar_fraction_propagates_to_complete_cycles() {
    let cfg = SpeedConfig::reference();
    let model = downscale(&model_by_name("mobilenetv2").unwrap(), 8);
    let r = run_model(&model, Precision::Int8, &cfg, Policy::Mixed).unwrap();
    let expect = (r.vector_cycles() as f64 * model.scalar_fraction) as u64;
    assert_eq!(r.complete_cycles() - r.vector_cycles(), expect);
    assert_eq!(r.scalar_cycles, expect);
}
