//! Property-based parity contract of the batch fast path.
//!
//! Batch-mode execution (`ExecMode::Batch`, consuming the compiler's
//! stream-run metadata) must be **bit-exact** against per-instruction
//! exact mode: identical `SimStats` (cycles, stalls, per-FU busy time,
//! instruction mix, MAC accounting, traffic by class) and identical
//! external-memory bytes, across random operator shapes, all three
//! precisions, every applicable strategy, and both functional and
//! timing-only runs.
//!
//! The deployment image vendors no proptest; properties are exercised with
//! a deterministic xorshift generator (same convention as
//! `proptest_invariants.rs`).

use speed_rvv::compiler::{compile_op, MemLayout};
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::dataflow;
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::ops::OpDesc;
use speed_rvv::sim::{ExecMode, Processor, SimStats};

/// Deterministic xorshift64* stream.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }

    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }

    fn operand(&mut self, p: Precision) -> i32 {
        let (lo, hi) = p.range();
        lo + (self.next() % (hi - lo + 1) as u64) as i32
    }
}

fn random_op(rng: &mut Rng) -> OpDesc {
    let prec = *rng.pick(&Precision::ALL);
    match rng.range(0, 3) {
        0 => OpDesc::mm(
            rng.range(1, 24) as u32,
            rng.range(1, 48) as u32,
            rng.range(1, 24) as u32,
            prec,
        ),
        1 => {
            let k = *rng.pick(&[1u32, 3, 5]);
            OpDesc::conv(
                rng.range(1, 12) as u32,
                rng.range(1, 16) as u32,
                rng.range(k as u64, 14) as u32,
                rng.range(k as u64, 14) as u32,
                k,
                rng.range(1, 2) as u32,
                k / 2,
                prec,
            )
        }
        2 => OpDesc::pwcv(
            rng.range(1, 16) as u32,
            rng.range(1, 16) as u32,
            rng.range(1, 12) as u32,
            rng.range(1, 12) as u32,
            prec,
        ),
        _ => OpDesc::dwcv(
            rng.range(1, 12) as u32,
            rng.range(3, 14) as u32,
            rng.range(3, 14) as u32,
            3,
            rng.range(1, 2) as u32,
            1,
            prec,
        ),
    }
}

/// Compile `op` once, execute the identical segments on a fresh machine in
/// `mode`, and return (per-run stats merged, lifetime stats, memory image
/// over the whole layout span).
fn run_mode(
    op: &OpDesc,
    strat: StrategyKind,
    functional: bool,
    mode: ExecMode,
    x: &[i32],
    w: &[i32],
) -> (SimStats, SimStats, Vec<u8>) {
    let cfg = SpeedConfig::reference();
    let span = MemLayout::required_bytes(op).max(1 << 16) as usize;
    let mut p = Processor::new(cfg, span);
    p.set_exec_mode(mode);
    let layout = MemLayout::for_op(op, span).unwrap();
    p.mem.preload_packed(layout.in_addr, x, op.prec);
    p.mem.preload_packed(layout.w_addr, w, op.prec);
    let c = compile_op(op, &cfg, strat, layout, functional).unwrap();
    p.set_plan(c.plan);
    let mut total = SimStats::default();
    for seg in &c.segments {
        total.merge(&p.run_segment(seg).unwrap());
    }
    // Fast-path sanity: the batch counters must account every instruction
    // the compiler emitted, exactly.
    assert_eq!(total.insns_total, c.summary.total_insns, "{op:?} {strat} {mode:?}");
    let image = p.mem.inspect(0, span).to_vec();
    (total, p.lifetime_stats().clone(), image)
}

/// Batch mode is bit-exact vs exact mode: stats, lifetime stats, and every
/// byte of external memory (outputs, partial spills, untouched regions).
#[test]
fn prop_batch_parity_stats_and_memory() {
    let mut rng = Rng::new(0xFA57);
    for case in 0..60 {
        let op = random_op(&mut rng);
        let x: Vec<i32> =
            (0..op.input_elems()).map(|_| rng.operand(op.prec)).collect();
        let w: Vec<i32> =
            (0..op.weight_elems()).map(|_| rng.operand(op.prec)).collect();
        let functional = case % 2 == 0;
        for strat in StrategyKind::ALL {
            if !dataflow::applicable(strat, &op) {
                continue;
            }
            let (se, le, me) = run_mode(&op, strat, functional, ExecMode::Exact, &x, &w);
            let (sb, lb, mb) = run_mode(&op, strat, functional, ExecMode::Batch, &x, &w);
            assert_eq!(se, sb, "case {case} {op:?} {strat} functional={functional}");
            assert_eq!(le, lb, "case {case} {op:?} {strat} lifetime");
            assert_eq!(me, mb, "case {case} {op:?} {strat} memory image");
        }
    }
}

/// The warm-engine path (program cache, persistent clock) is also
/// mode-invariant: a whole model run produces identical aggregate stats.
#[test]
fn prop_engine_model_runs_mode_invariant() {
    use speed_rvv::models::zoo::Model;
    use speed_rvv::{Engine, Precision};

    let model = Model {
        name: "parity",
        ops: vec![
            OpDesc::conv(4, 8, 10, 10, 3, 1, 1, Precision::Int8),
            OpDesc::pwcv(8, 8, 10, 10, Precision::Int8),
            OpDesc::dwcv(8, 10, 10, 3, 1, 1, Precision::Int8),
            OpDesc::mm(10, 8, 12, Precision::Int8),
        ],
        scalar_fraction: 0.1,
    };
    let run = |mode: ExecMode| {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        engine.set_exec_mode(mode);
        let mut session = engine.session();
        let mut results = Vec::new();
        for prec in Precision::ALL {
            // Two passes per precision: the second replays cached programs.
            results.push(session.run_model(&model, prec).unwrap().total);
            results.push(session.run_model(&model, prec).unwrap().total);
        }
        results
    };
    let exact = run(ExecMode::Exact);
    let batch = run(ExecMode::Batch);
    assert_eq!(exact.len(), batch.len());
    for (i, (e, b)) in exact.iter().zip(&batch).enumerate() {
        assert_eq!(e, b, "pass {i}");
    }
}

/// The partial-spill schedule (FFCS when a block's all-F partials exceed
/// the VRF partial partition: `f/lanes × 4 × ow > vrf/3`, i.e. wide
/// feature maps at F=64) also survives the fast path bit-exactly — this
/// exercises the `VLE` reload runs and the partial-region `VSE` runs.
#[test]
fn prop_partial_spill_paths_agree() {
    let mut rng = Rng::new(2718);
    for (c, functional) in [(16u32, false), (20, true)] {
        // Spill needs both: 64 output channels × ow=90 → 5760 B of
        // partials per output row per lane > the 5461 B partition budget,
        // AND c > conv_c_chunk (14 at INT16/K=3) so the channel loop
        // revisits blocks and round-trips partials through DRAM.
        let op = OpDesc::conv(c, 64, 90, 90, 3, 1, 1, Precision::Int16);
        let x: Vec<i32> =
            (0..op.input_elems()).map(|_| rng.operand(op.prec)).collect();
        let w: Vec<i32> =
            (0..op.weight_elems()).map(|_| rng.operand(op.prec)).collect();
        let (se, _, me) =
            run_mode(&op, StrategyKind::Ffcs, functional, ExecMode::Exact, &x, &w);
        let (sb, _, mb) =
            run_mode(&op, StrategyKind::Ffcs, functional, ExecMode::Batch, &x, &w);
        assert!(
            se.traffic.partial_write > 0 && se.traffic.partial_read > 0,
            "case must actually spill partials ({op:?}): {:?}",
            se.traffic
        );
        assert_eq!(se, sb, "{op:?}");
        assert_eq!(me, mb, "{op:?}");
    }
}
