//! Tuner-semantics parity: every mapping the auto-tuner may select must
//! be a pure re-labeling of the static mixed mapping's work — bit-identical
//! output memory, identical MAC accounting — across random shapes, every
//! operator kind, and every supported precision. The deployment image
//! vendors no proptest; properties run over a deterministic xorshift
//! stream, same spirit as `proptest_invariants.rs`.

use std::sync::Arc;

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::Policy;
use speed_rvv::dataflow::MappingChoice;
use speed_rvv::engine::Engine;
use speed_rvv::models::ops::OpDesc;
use speed_rvv::models::zoo::model_by_name;
use speed_rvv::report::fig12::downscale;
use speed_rvv::tune::{
    candidates_for, functional_output, tune_model, tune_op, verify_choice, TuneOptions,
    TunedPlan,
};

/// Deterministic xorshift64* stream.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }

    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }
}

/// A random *valid* operator of any kind at `prec`, kept small enough
/// that functional simulation (O(MACs)) stays cheap.
fn random_op(rng: &mut Rng, prec: Precision) -> OpDesc {
    match rng.range(0, 3) {
        0 => OpDesc::mm(
            rng.range(1, 16) as u32,
            rng.range(1, 48) as u32,
            rng.range(1, 16) as u32,
            prec,
        ),
        1 => {
            let k = *rng.pick(&[1u32, 3, 5]);
            OpDesc::conv(
                rng.range(1, 24) as u32,
                rng.range(1, 16) as u32,
                rng.range(k as u64, 13) as u32,
                rng.range(k as u64, 13) as u32,
                k,
                rng.range(1, 2) as u32,
                k / 2,
                prec,
            )
        }
        2 => OpDesc::pwcv(
            rng.range(1, 24) as u32,
            rng.range(1, 16) as u32,
            rng.range(1, 10) as u32,
            rng.range(1, 10) as u32,
            prec,
        ),
        _ => OpDesc::dwcv(
            rng.range(1, 12) as u32,
            rng.range(3, 13) as u32,
            rng.range(3, 13) as u32,
            3,
            rng.range(1, 2) as u32,
            1,
            prec,
        ),
    }
}

/// The tentpole property: for random shapes across all precisions, the
/// tuner-selected mapping produces output memory bit-identical to the
/// static mixed mapping, never costs more simulated cycles, and is
/// reproducible.
#[test]
fn prop_tuned_selection_bit_identical_and_never_slower() {
    let cfg = SpeedConfig::reference();
    let opts = TuneOptions::default();
    let mut engine = Engine::new(cfg).unwrap();
    let mut rng = Rng::new(0x7E57_5EED);
    for prec in Precision::ALL {
        for case in 0..12 {
            let op = random_op(&mut rng, prec);
            op.validate().unwrap_or_else(|e| panic!("{op:?}: {e}"));
            let t = tune_op(&mut engine, &op, &opts)
                .unwrap_or_else(|e| panic!("case {case} {op:?}: {e}"));
            assert!(
                t.cycles <= t.static_cycles,
                "case {case} {op:?}: tuned {} > static {}",
                t.cycles,
                t.static_cycles
            );
            // Bit-identical outputs vs the static mapping.
            verify_choice(&cfg, &op, t.choice)
                .unwrap_or_else(|e| panic!("case {case} {op:?}: {e}"));
        }
    }
}

/// Stronger (smaller) sweep: *every* candidate the tuner could possibly
/// pick — not just the winner — matches the static mapping bit for bit,
/// and the functional run's MAC count is the operator's.
#[test]
fn prop_every_candidate_bit_identical() {
    let cfg = SpeedConfig::reference();
    let opts = TuneOptions::default();
    let mut rng = Rng::new(99);
    for prec in Precision::ALL {
        for _ in 0..4 {
            let op = random_op(&mut rng, prec);
            let want =
                functional_output(&cfg, &op, MappingChoice::preferred(&op), 11).unwrap();
            for choice in candidates_for(&op, &cfg, &opts) {
                let got = functional_output(&cfg, &op, choice, 11)
                    .unwrap_or_else(|e| panic!("{op:?} {choice}: {e}"));
                assert_eq!(got, want, "{op:?} {choice}");
            }
        }
    }
}

/// The honest-spill acceptance pair: F=604 (last VRF-resident) and F=608
/// (first spilled) INT8 3x3 CONVs straddle the FF weight-residency
/// boundary on the reference configuration. Both sides must be
/// bit-identical to the static mapping under FF, tune without losing to
/// static, and report identical cycles/traffic in batch and exact mode —
/// the refetch runs are real emitted instructions, not a cost fiction.
#[test]
fn ff_spill_boundary_pair_is_honest_across_modes() {
    use speed_rvv::isa::StrategyKind;
    use speed_rvv::sim::ExecMode;
    let cfg = SpeedConfig::reference();
    let opts = TuneOptions::default();
    for f in [604u32, 608] {
        let op = OpDesc::conv(8, f, 6, 6, 3, 1, 1, Precision::Int8);
        let ff = MappingChoice::of(StrategyKind::Ff);
        // Bit-identical output memory vs the static mapping, spilled or not.
        verify_choice(&cfg, &op, ff).unwrap_or_else(|e| panic!("F={f}: {e}"));
        let mut engine = Engine::new(cfg).unwrap();
        let t = tune_op(&mut engine, &op, &opts).unwrap();
        assert!(
            t.cycles <= t.static_cycles,
            "F={f}: tuned {} > static {}",
            t.cycles,
            t.static_cycles
        );
        // Batch and exact agree bit-for-bit on the FF stream.
        engine.quiesce();
        let (batch, _) = engine.run_op_with(&op, ff, false).unwrap();
        let mut exact_engine = Engine::new(cfg).unwrap();
        exact_engine.set_exec_mode(ExecMode::Exact);
        let (exact, _) = exact_engine.run_op_with(&op, ff, false).unwrap();
        assert_eq!(batch.cycles, exact.cycles, "F={f}");
        assert_eq!(batch.traffic, exact.traffic, "F={f}");
        assert_eq!(batch.macs, op.total_macs(), "F={f}");
    }
}

/// Whole-model integration: a tuned plan for a downscaled CONV-heavy zoo
/// model round-trips through JSON, never regresses the composed model
/// run, and Policy::Tuned layer-for-layer follows the plan.
#[test]
fn tuned_model_round_trips_and_never_regresses() {
    let cfg = SpeedConfig::reference();
    let model = downscale(&model_by_name("vgg16").unwrap(), 16);
    for prec in [Precision::Int4, Precision::Int8] {
        let plan = tune_model(&cfg, &model, prec, &TuneOptions::default()).unwrap();
        // JSON round-trip through the persistent-cache representation.
        let back = TunedPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back, "{prec}");
        assert!(plan.speedup() >= 1.0);

        let mut static_engine = Engine::new(cfg).unwrap();
        let static_run = static_engine
            .session()
            .with_policy(Policy::Mixed)
            .run_model(&model, prec)
            .unwrap();
        let mut tuned_engine = Engine::new(cfg).unwrap();
        let plan = Arc::new(plan);
        let tuned_run = tuned_engine
            .session()
            .with_tuned_plan(plan.clone())
            .run_model(&model, prec)
            .unwrap();
        assert_eq!(tuned_run.total.macs, static_run.total.macs, "{prec}");
        assert_eq!(tuned_run.layers.len(), static_run.layers.len(), "{prec}");
        assert!(
            tuned_run.total.cycles <= static_run.total.cycles,
            "{prec}: tuned {} > static {}",
            tuned_run.total.cycles,
            static_run.total.cycles
        );
        for layer in &tuned_run.layers {
            assert_eq!(
                layer.strat,
                plan.choice_for(&layer.op).unwrap().strat,
                "{prec} {:?}",
                layer.op
            );
        }
    }
}
