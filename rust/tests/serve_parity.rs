//! Serving determinism contract (ISSUE 3 acceptance bar): for a fixed
//! scenario seed, per-request `SimStats` are bit-identical regardless of
//! `--workers`, of the micro-batch cap, and of batch-vs-`--exact`
//! simulation mode — scheduling is semantics-preserving.

use speed_rvv::config::SpeedConfig;
use speed_rvv::serve::{
    stats_digest, RequestKind, RequestResult, Scenario, ServeOptions, ServePool,
};
use speed_rvv::sim::ExecMode;
use speed_rvv::Engine;

/// A small fixed scenario: cheap enough for the exact-mode leg, rich
/// enough to mix models, operators, and all three precisions.
const PARITY_SCENARIO: &str = r#"{
    "name": "parity",
    "seed": 20240917,
    "requests": 10,
    "arrival": { "pattern": "burst", "size": 4 },
    "mix": [
        { "model": "mobilenetv2", "prec": 8, "weight": 2, "downscale": 8 },
        { "model": "vit_tiny", "prec": 4, "weight": 2, "downscale": 8 },
        { "op": "mm", "m": 24, "k": 32, "n": 24, "prec": 16, "weight": 2 },
        { "op": "dwcv", "c": 8, "h": 12, "w": 12, "ksize": 3, "prec": 8,
          "weight": 1 }
    ]
}"#;

fn run_pool(
    kinds: &[RequestKind],
    workers: usize,
    max_batch: usize,
    mode: ExecMode,
) -> Vec<RequestResult> {
    let pool = ServePool::new(
        SpeedConfig::reference(),
        ServeOptions {
            workers,
            capacity: 64,
            max_batch,
            exec_mode: mode,
            ..Default::default()
        },
    )
    .unwrap();
    pool.run_all(kinds.to_vec()).unwrap()
}

fn assert_same_stats(a: &[RequestResult], b: &[RequestResult], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{what}");
        assert_eq!(x.stats, y.stats, "{what}: request {} ({})", x.id, x.layers);
        assert_eq!(x.layers, y.layers, "{what}: request {}", x.id);
    }
    assert_eq!(stats_digest(a), stats_digest(b), "{what}: digest");
}

#[test]
fn per_request_stats_are_schedule_invariant() {
    let sc = Scenario::from_json(PARITY_SCENARIO).unwrap();
    let kinds = sc.generate(false).unwrap();
    assert_eq!(kinds.len(), 10);

    // Reference: one worker, no coalescing, batch-mode simulator.
    let reference = run_pool(&kinds, 1, 1, ExecMode::Batch);

    // More workers (work stealing + affinity routing engaged).
    let wide = run_pool(&kinds, 4, 1, ExecMode::Batch);
    assert_same_stats(&reference, &wide, "workers 1 vs 4");

    // Micro-batching on.
    let batched = run_pool(&kinds, 2, 8, ExecMode::Batch);
    assert_same_stats(&reference, &batched, "batched vs unbatched");

    // The per-instruction simulator (--exact) with everything else varied.
    let exact = run_pool(&kinds, 3, 4, ExecMode::Exact);
    assert_same_stats(&reference, &exact, "batch vs exact mode");
}

#[test]
fn pool_results_match_a_dedicated_fresh_engine() {
    // Semantics preservation against the strongest baseline: each request
    // run alone on its own brand-new engine. Only the precision-switch
    // field needs the documented normalization (the pool reports
    // intra-request switches; a fresh engine additionally counts the
    // warm-up switch its default INT8 datapath may pay on entry).
    let sc = Scenario::from_json(PARITY_SCENARIO).unwrap();
    let kinds = sc.generate(false).unwrap();
    let served = run_pool(&kinds, 2, 4, ExecMode::Batch);
    for (kind, r) in kinds.iter().zip(&served) {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let mut solo = match kind {
            RequestKind::Model { model, prec, policy } => {
                let mut session = engine.session().with_policy(*policy);
                session.run_model(model, *prec).unwrap().total
            }
            RequestKind::Op { op, strat } => {
                engine.session().run_op(op, *strat).unwrap().stats
            }
        };
        solo.precision_switches = r.stats.precision_switches;
        assert_eq!(solo, r.stats, "request {} ({})", r.id, kind.label());
    }
}

#[test]
fn committed_mixed_edge_scenario_is_deterministic() {
    // The CI smoke scenario itself: hermetic (committed file), and its
    // quick-mode request stream serves identically on 1 and 4 workers.
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../bench/scenarios/mixed_edge.json"
    );
    let sc = Scenario::load(path).unwrap();
    assert_eq!(sc.name, "mixed_edge");
    let kinds = sc.generate(true).unwrap();
    assert!(!kinds.is_empty());
    let narrow = run_pool(&kinds, 1, 1, ExecMode::Batch);
    let wide = run_pool(&kinds, 4, 8, ExecMode::Batch);
    assert_same_stats(&narrow, &wide, "mixed_edge quick");
    // The stream mixes precisions (the scenario's point).
    let precs: std::collections::HashSet<String> =
        kinds.iter().map(|k| format!("{}", k.precision())).collect();
    assert!(precs.len() >= 2, "{precs:?}");
}

#[test]
fn other_committed_scenarios_parse_and_generate() {
    for file in ["steady_vision.json", "vit_burst.json"] {
        let path =
            format!("{}/../bench/scenarios/{file}", env!("CARGO_MANIFEST_DIR"));
        let sc = Scenario::load(&path).unwrap_or_else(|e| panic!("{file}: {e}"));
        let kinds = sc.generate(true).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert!(!kinds.is_empty(), "{file}");
    }
}

#[test]
fn serve_bench_report_is_parseable_and_digest_stable() {
    use speed_rvv::runtime::json::{parse, Json};
    use speed_rvv::serve::{run_serve_bench, ServeBenchOptions};
    let sc = Scenario::from_json(PARITY_SCENARIO).unwrap();
    let a = run_serve_bench(
        &sc,
        &ServeBenchOptions {
            workers: 1,
            quick: false,
            exact: false,
            max_batch: Some(1),
            tuned: false,
        },
    )
    .unwrap();
    let b = run_serve_bench(
        &sc,
        &ServeBenchOptions {
            workers: 3,
            quick: false,
            exact: false,
            max_batch: None,
            tuned: false,
        },
    )
    .unwrap();
    assert_eq!(a.stats_digest, b.stats_digest, "digest is schedule-invariant");
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.total_macs, b.total_macs);
    assert_eq!(a.total_traffic_bytes, b.total_traffic_bytes);

    let doc = parse(&b.to_json()).unwrap();
    assert_eq!(doc.get("schema").and_then(Json::as_i64), Some(1));
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve-bench"));
    assert_eq!(doc.get("requests").and_then(Json::as_i64), Some(10));
    assert_eq!(
        doc.get("stats_digest").and_then(Json::as_str),
        Some(format!("{:016x}", a.stats_digest).as_str())
    );
    let metrics = doc.get("metrics").expect("metrics object");
    assert_eq!(metrics.get("completed").and_then(Json::as_i64), Some(10));
    assert!(metrics.get("latency_us").and_then(|l| l.get("p99")).is_some());
    assert!(metrics.get("precision_switches").is_some());
}

#[test]
fn backpressure_blocks_then_drains() {
    // A capacity-2 pool with one worker and a stream of requests: the
    // blocking submit path must apply backpressure (never drop), and
    // everything drains to completion.
    let pool = ServePool::new(
        SpeedConfig::reference(),
        ServeOptions {
            workers: 1,
            capacity: 2,
            max_batch: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let kinds: Vec<RequestKind> = Scenario::from_json(PARITY_SCENARIO)
        .unwrap()
        .generate(false)
        .unwrap();
    let n = kinds.len() as u64;
    let results = pool.run_all(kinds).unwrap();
    assert_eq!(results.len() as u64, n);
    let snap = pool.shutdown();
    assert_eq!(snap.completed, n);
    assert_eq!(snap.rejected, 0);
    assert!(snap.queue_max_depth <= 2);
}
