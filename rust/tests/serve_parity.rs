//! Serving determinism contract (ISSUE 3 acceptance bar): for a fixed
//! scenario seed, per-request `SimStats` are bit-identical regardless of
//! `--workers`, of the micro-batch cap, and of batch-vs-`--exact`
//! simulation mode — scheduling is semantics-preserving.

use speed_rvv::config::SpeedConfig;
use speed_rvv::serve::{
    stats_digest, Phase, Request, RequestKind, RequestResult, Scenario, ServeOptions, ServePool,
};
use speed_rvv::sim::ExecMode;
use speed_rvv::Engine;

/// A small fixed scenario: cheap enough for the exact-mode leg, rich
/// enough to mix models, operators, and all three precisions.
const PARITY_SCENARIO: &str = r#"{
    "name": "parity",
    "seed": 20240917,
    "requests": 10,
    "arrival": { "pattern": "burst", "size": 4 },
    "mix": [
        { "model": "mobilenetv2", "prec": 8, "weight": 2, "downscale": 8 },
        { "model": "vit_tiny", "prec": 4, "weight": 2, "downscale": 8 },
        { "op": "mm", "m": 24, "k": 32, "n": 24, "prec": 16, "weight": 2 },
        { "op": "dwcv", "c": 8, "h": 12, "w": 12, "ksize": 3, "prec": 8,
          "weight": 1 }
    ]
}"#;

fn run_pool(
    reqs: &[Request],
    workers: usize,
    max_batch: usize,
    mode: ExecMode,
) -> Vec<RequestResult> {
    let pool = ServePool::new(
        SpeedConfig::reference(),
        ServeOptions {
            workers,
            capacity: 64,
            max_batch,
            exec_mode: mode,
            ..Default::default()
        },
    )
    .unwrap();
    pool.run_all(reqs.to_vec()).unwrap()
}

fn assert_same_stats(a: &[RequestResult], b: &[RequestResult], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{what}");
        assert_eq!(x.stats, y.stats, "{what}: request {} ({})", x.id, x.layers);
        assert_eq!(x.layers, y.layers, "{what}: request {}", x.id);
    }
    assert_eq!(stats_digest(a), stats_digest(b), "{what}: digest");
}

#[test]
fn per_request_stats_are_schedule_invariant() {
    let sc = Scenario::from_json(PARITY_SCENARIO).unwrap();
    let reqs = sc.generate(false).unwrap();
    assert_eq!(reqs.len(), 10);

    // Reference: one worker, no coalescing, batch-mode simulator.
    let reference = run_pool(&reqs, 1, 1, ExecMode::Batch);

    // More workers (work stealing + affinity routing engaged).
    let wide = run_pool(&reqs, 4, 1, ExecMode::Batch);
    assert_same_stats(&reference, &wide, "workers 1 vs 4");

    // Micro-batching on.
    let batched = run_pool(&reqs, 2, 8, ExecMode::Batch);
    assert_same_stats(&reference, &batched, "batched vs unbatched");

    // The per-instruction simulator (--exact) with everything else varied.
    let exact = run_pool(&reqs, 3, 4, ExecMode::Exact);
    assert_same_stats(&reference, &exact, "batch vs exact mode");
}

#[test]
fn pool_results_match_a_dedicated_fresh_engine() {
    // Semantics preservation against the strongest baseline: each request
    // run alone on its own brand-new engine. Only the precision-switch
    // field needs the documented normalization (the pool reports
    // intra-request switches; a fresh engine additionally counts the
    // warm-up switch its default INT8 datapath may pay on entry).
    let sc = Scenario::from_json(PARITY_SCENARIO).unwrap();
    let reqs = sc.generate(false).unwrap();
    let served = run_pool(&reqs, 2, 4, ExecMode::Batch);
    for (req, r) in reqs.iter().zip(&served) {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        let mut solo = match &req.kind {
            RequestKind::Model { model, prec, policy } => {
                let mut session = engine.session().with_policy(*policy);
                session.run_model(model, *prec).unwrap().total
            }
            RequestKind::Op { op, strat } => {
                engine.session().run_op(op, *strat).unwrap().stats
            }
        };
        solo.precision_switches = r.stats.precision_switches;
        assert_eq!(solo, r.stats, "request {} ({})", r.id, req.kind.label());
    }
}

#[test]
fn committed_mixed_edge_scenario_is_deterministic() {
    // The CI smoke scenario itself: hermetic (committed file), and its
    // quick-mode request stream serves identically on 1 and 4 workers.
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../bench/scenarios/mixed_edge.json"
    );
    let sc = Scenario::load(path).unwrap();
    assert_eq!(sc.name, "mixed_edge");
    let reqs = sc.generate(true).unwrap();
    assert!(!reqs.is_empty());
    let narrow = run_pool(&reqs, 1, 1, ExecMode::Batch);
    let wide = run_pool(&reqs, 4, 8, ExecMode::Batch);
    assert_same_stats(&narrow, &wide, "mixed_edge quick");
    // The stream mixes precisions (the scenario's point).
    let precs: std::collections::HashSet<String> =
        reqs.iter().map(|k| format!("{}", k.kind.precision())).collect();
    assert!(precs.len() >= 2, "{precs:?}");
}

#[test]
fn other_committed_scenarios_parse_and_generate() {
    for file in [
        "steady_vision.json",
        "vit_burst.json",
        "online_tune.json",
        "llm_decode.json",
    ] {
        let path =
            format!("{}/../bench/scenarios/{file}", env!("CARGO_MANIFEST_DIR"));
        let sc = Scenario::load(&path).unwrap_or_else(|e| panic!("{file}: {e}"));
        let reqs = sc.generate(true).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert!(!reqs.is_empty(), "{file}");
    }
}

#[test]
fn serve_bench_report_is_parseable_and_digest_stable() {
    use speed_rvv::runtime::json::{parse, Json};
    use speed_rvv::serve::{run_serve_bench, ServeBenchOptions};
    let sc = Scenario::from_json(PARITY_SCENARIO).unwrap();
    let a = run_serve_bench(
        &sc,
        &ServeBenchOptions {
            workers: 1,
            quick: false,
            exact: false,
            max_batch: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    let b = run_serve_bench(
        &sc,
        &ServeBenchOptions {
            workers: 3,
            quick: false,
            exact: false,
            max_batch: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(a.stats_digest, b.stats_digest, "digest is schedule-invariant");
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.total_macs, b.total_macs);
    assert_eq!(a.total_traffic_bytes, b.total_traffic_bytes);

    let doc = parse(&b.to_json()).unwrap();
    assert_eq!(doc.get("schema").and_then(Json::as_i64), Some(2));
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve-bench"));
    assert_eq!(doc.get("requests").and_then(Json::as_i64), Some(10));
    assert_eq!(
        doc.get("stats_digest").and_then(Json::as_str),
        Some(format!("{:016x}", a.stats_digest).as_str())
    );
    let metrics = doc.get("metrics").expect("metrics object");
    assert_eq!(metrics.get("completed").and_then(Json::as_i64), Some(10));
    assert!(metrics.get("latency_us").and_then(|l| l.get("p99")).is_some());
    assert!(metrics.get("precision_switches").is_some());
}

/// Deterministic xorshift64* stream for the randomized property tests
/// (the image vendors no proptest; same spirit as `tune_parity.rs`).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

/// A random small valid request: mostly single operators across all
/// precisions, occasionally a tiny model (mixed or online-tuned).
fn random_kind(rng: &mut Rng) -> RequestKind {
    use speed_rvv::coordinator::Policy;
    use speed_rvv::isa::StrategyKind;
    use speed_rvv::models::zoo::Model;
    use speed_rvv::models::OpDesc;
    let prec = match rng.range(0, 2) {
        0 => speed_rvv::Precision::Int16,
        1 => speed_rvv::Precision::Int8,
        _ => speed_rvv::Precision::Int4,
    };
    match rng.range(0, 9) {
        0 => RequestKind::Model {
            model: Model {
                name: "prop_tiny",
                ops: vec![
                    OpDesc::conv(4, 8, 8, 8, 3, 1, 1, prec),
                    OpDesc::mm(6, 8, 10, prec),
                ],
                scalar_fraction: 0.1,
            },
            prec,
            policy: if rng.range(0, 1) == 0 { Policy::Mixed } else { Policy::TunedOnline },
        },
        1..=4 => RequestKind::Op {
            op: OpDesc::mm(
                rng.range(1, 10) as u32,
                rng.range(1, 16) as u32,
                rng.range(1, 10) as u32,
                prec,
            ),
            strat: StrategyKind::Mm,
        },
        5..=6 => {
            let op = OpDesc::pwcv(
                rng.range(1, 8) as u32,
                rng.range(1, 8) as u32,
                rng.range(1, 8) as u32,
                rng.range(1, 8) as u32,
                prec,
            );
            RequestKind::Op { op, strat: StrategyKind::Cf }
        }
        _ => {
            let op = OpDesc::dwcv(
                rng.range(1, 8) as u32,
                rng.range(3, 9) as u32,
                rng.range(3, 9) as u32,
                3,
                1,
                1,
                prec,
            );
            RequestKind::Op { op, strat: StrategyKind::Ff }
        }
    }
}

#[test]
fn prop_random_streams_lose_nothing_and_replay_bit_identically() {
    // Scheduler + online-tuner property sweep: for random request
    // streams, pool geometries, and steal thresholds, (1) every submitted
    // request completes exactly once, in submission-id order, with
    // nothing lost, duplicated, or left in flight; (2) an independent
    // pool replaying the same stream under a different geometry reports
    // bit-identical per-request stats; (3) the routing counters account
    // for exactly the submitted requests.
    let mut rng = Rng::new(0xC0FF_EE05);
    for trial in 0..4 {
        let n = rng.range(12, 28) as usize;
        let kinds: Vec<RequestKind> = (0..n).map(|_| random_kind(&mut rng)).collect();
        let geom = |rng: &mut Rng| {
            (
                rng.range(1, 4) as usize,  // workers
                rng.range(1, 8) as usize,  // max_batch
                rng.range(1, 3) as usize,  // steal threshold
            )
        };
        let (w1, b1, s1) = geom(&mut rng);
        let (w2, b2, s2) = geom(&mut rng);
        let run = |workers, max_batch, steal_threshold| {
            let pool = ServePool::new(
                SpeedConfig::reference(),
                ServeOptions {
                    workers,
                    capacity: 64,
                    max_batch,
                    steal_threshold,
                    ..Default::default()
                },
            )
            .unwrap();
            let results = pool.run_all(kinds.clone()).unwrap();
            (results, pool.shutdown())
        };
        let (a, snap_a) = run(w1, b1, s1);
        let (b, snap_b) = run(w2, b2, s2);
        // (1) nothing lost or duplicated; ids are the submission order.
        assert_eq!(a.len(), n, "trial {trial}");
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64, "trial {trial}");
        }
        assert_eq!(snap_a.submitted, n as u64, "trial {trial}");
        assert_eq!(snap_a.completed + snap_a.failed, n as u64, "trial {trial}");
        assert_eq!(snap_a.in_flight, 0, "trial {trial}");
        assert_eq!(snap_a.rejected, 0, "blocking submit never drops");
        // (2) schedule-invariant stats across geometries.
        assert_same_stats(&a, &b, &format!("trial {trial}: {w1}/{b1}/{s1} vs {w2}/{b2}/{s2}"));
        // (3) every routed request is an affinity hit or miss, exactly once.
        assert_eq!(
            snap_a.affinity_hits + snap_a.affinity_misses,
            n as u64,
            "trial {trial}"
        );
        assert_eq!(snap_b.completed + snap_b.failed, n as u64, "trial {trial}");
        // Queue depth can never exceed the configured capacity.
        assert!(snap_a.queue_max_depth <= 64, "trial {trial}");
    }
}

#[test]
fn single_precision_streams_miss_affinity_exactly_once() {
    // Precision-affinity invariant: in an all-one-precision stream only
    // the very first request can miss (no lane has the affinity yet);
    // every later request finds a matching lane, and stealing — which
    // transfers same-precision work — never breaks the invariant.
    let kinds: Vec<RequestKind> = (0..12)
        .map(|i| RequestKind::Op {
            op: speed_rvv::models::OpDesc::mm(2 + (i % 4), 8, 4, speed_rvv::Precision::Int8),
            strat: speed_rvv::isa::StrategyKind::Mm,
        })
        .collect();
    for workers in [1usize, 3] {
        let pool = ServePool::new(
            SpeedConfig::reference(),
            ServeOptions { workers, capacity: 64, max_batch: 2, steal_threshold: 2, ..Default::default() },
        )
        .unwrap();
        pool.run_all(kinds.clone()).unwrap();
        let snap = pool.shutdown();
        assert_eq!(snap.affinity_misses, 1, "workers={workers}");
        assert_eq!(snap.affinity_hits, 11, "workers={workers}");
    }
}

#[test]
fn huge_steal_threshold_disables_stealing() {
    // The steal-threshold contract: below the threshold a backed-up lane
    // keeps its affinity run, so an unreachable threshold must yield zero
    // steals however unbalanced the lanes get.
    let kinds: Vec<RequestKind> = (0..16)
        .map(|i| RequestKind::Op {
            op: speed_rvv::models::OpDesc::mm(2 + (i % 5), 6, 4, speed_rvv::Precision::Int8),
            strat: speed_rvv::isa::StrategyKind::Mm,
        })
        .collect();
    let pool = ServePool::new(
        SpeedConfig::reference(),
        ServeOptions {
            workers: 3,
            capacity: 64,
            max_batch: 1,
            steal_threshold: usize::MAX,
            ..Default::default()
        },
    )
    .unwrap();
    pool.run_all(kinds).unwrap();
    let snap = pool.shutdown();
    assert_eq!(snap.steals, 0);
    assert_eq!(snap.completed, 16);
}

#[test]
fn backpressure_blocks_then_drains() {
    // A capacity-2 pool with one worker and a stream of requests: the
    // blocking submit path must apply backpressure (never drop), and
    // everything drains to completion.
    let pool = ServePool::new(
        SpeedConfig::reference(),
        ServeOptions {
            workers: 1,
            capacity: 2,
            max_batch: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let reqs: Vec<Request> = Scenario::from_json(PARITY_SCENARIO)
        .unwrap()
        .generate(false)
        .unwrap();
    let n = reqs.len() as u64;
    let results = pool.run_all(reqs).unwrap();
    assert_eq!(results.len() as u64, n);
    let snap = pool.shutdown();
    assert_eq!(snap.completed, n);
    assert_eq!(snap.rejected, 0);
    assert!(snap.queue_max_depth <= 2);
}

/// Version-2 scenario exercising the llm workload: three autoregressive
/// sessions (one prefill + five decode steps each) across two precisions.
const LLM_SCENARIO: &str = r#"{
    "name": "llm_parity",
    "version": 2,
    "seed": 7,
    "requests": 18,
    "arrival": { "pattern": "burst", "size": 4 },
    "mix": [
        { "llm": "llm_tiny", "prompt": 8, "decode": 5, "prec": 8, "weight": 2 },
        { "llm": "llm_tiny", "prompt": 8, "decode": 5, "prec": 4, "weight": 1 }
    ]
}"#;

#[test]
fn decode_parity_across_worker_counts_with_kv_accounting() {
    // The ISSUE 7 acceptance bar: session affinity routes decode steps to
    // the worker holding KV residency, yet per-request stats stay
    // bit-identical for any worker count — residency decides only WHERE a
    // request runs, never WHAT it computes.
    let sc = Scenario::from_json(LLM_SCENARIO).unwrap();
    let reqs = sc.generate(false).unwrap();
    assert_eq!(reqs.len(), 18);
    let decodes = reqs.iter().filter(|r| r.phase == Phase::Decode).count() as u64;
    assert_eq!(decodes, 15, "3 sessions x 5 decode steps");
    assert!(reqs.iter().all(|r| r.session.is_some() && r.kv_bytes > 0));

    let run = |workers: usize, kv_capacity: u64| {
        let pool = ServePool::new(
            SpeedConfig::reference(),
            ServeOptions {
                workers,
                capacity: 64,
                max_batch: 4,
                kv_capacity,
                ..Default::default()
            },
        )
        .unwrap();
        let results = pool.run_all(reqs.clone()).unwrap();
        (results, pool.shutdown())
    };

    // Ample KV budget (0 = unlimited): every decode step lands on its
    // session's resident worker, and the phase split is fully accounted.
    let (narrow, snap1) = run(1, 0);
    let (wide, snap4) = run(4, 0);
    assert_same_stats(&narrow, &wide, "llm decode workers 1 vs 4");
    for snap in [&snap1, &snap4] {
        assert_eq!(snap.prefill_requests, reqs.len() as u64 - decodes);
        assert_eq!(snap.decode_requests, decodes);
        assert_eq!(snap.kv_hits, decodes);
        assert_eq!(snap.kv_misses, 0);
        assert_eq!(snap.kv_spills, 0);
        assert!(snap.kv_bytes_peak > 0);
    }

    // A starved per-worker KV budget forces evictions (spills) — but
    // residency is scheduling-only, so the stats remain bit-identical.
    let (starved, snap_tiny) = run(4, 1);
    assert_same_stats(&narrow, &starved, "llm decode with starved kv budget");
    assert!(snap_tiny.kv_spills > 0);
}
