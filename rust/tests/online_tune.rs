//! Online first-request tuning (ISSUE 5 acceptance bar): the first
//! `Policy::TunedOnline` request for an uncovered `(model, precision,
//! config-sig)` key tunes on its owning worker and publishes the plan to
//! the pool's shared `TunedPlans` registry; every later same-key request
//! replays it with bit-identical per-request stats. The plan the pool
//! converges to is the plan offline `repro tune` produces for the same
//! workload, and a tune stall on one worker never blocks other lanes.

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::Policy;
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::zoo::Model;
use speed_rvv::models::OpDesc;
use speed_rvv::serve::{
    stats_digest, RequestKind, RequestResult, ServeOptions, ServePool,
};
use speed_rvv::tune::{tune_model, TuneOptions, TunedPlans};

fn cfg() -> SpeedConfig {
    SpeedConfig::reference()
}

/// A small CONV-heavy model: cheap to tune, rich enough that every
/// operator class (and therefore every strategy family) participates.
fn tiny_model() -> Model {
    Model {
        name: "tiny_online",
        ops: vec![
            OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int8),
            OpDesc::pwcv(8, 16, 10, 10, Precision::Int8),
            OpDesc::dwcv(16, 10, 10, 3, 1, 1, Precision::Int8),
            OpDesc::mm(10, 16, 24, Precision::Int8),
            OpDesc::conv(8, 8, 12, 12, 3, 1, 1, Precision::Int8),
        ],
        scalar_fraction: 0.1,
    }
}

fn online_kind(prec: Precision) -> RequestKind {
    RequestKind::Model { model: tiny_model(), prec, policy: Policy::TunedOnline }
}

fn small_op(prec: Precision, m: u32) -> RequestKind {
    RequestKind::Op { op: OpDesc::mm(m, 8, 4, prec), strat: StrategyKind::Mm }
}

fn pool_with(
    registry: TunedPlans,
    workers: usize,
    max_batch: usize,
    steal_threshold: usize,
) -> ServePool {
    ServePool::new_tuned(
        cfg(),
        ServeOptions {
            workers,
            capacity: 64,
            max_batch,
            steal_threshold,
            ..Default::default()
        },
        registry,
    )
    .unwrap()
}

#[test]
fn second_request_is_served_from_the_shared_registry_bit_identically() {
    // One worker, no coalescing: request 0 must stall (tune + publish),
    // requests 1 and 2 must hit the published plan, and all three must
    // report bit-identical per-request stats — the stall is wall time,
    // never simulated work.
    let registry = TunedPlans::new();
    let pool = pool_with(registry.clone(), 1, 1, 2);
    let kinds = vec![
        online_kind(Precision::Int8),
        online_kind(Precision::Int8),
        online_kind(Precision::Int8),
    ];
    let results = pool.run_all(kinds).unwrap();
    assert_eq!(results[0].stats, results[1].stats, "stall vs registry replay");
    assert_eq!(results[1].stats, results[2].stats);
    assert_eq!(results[0].layers, results[1].layers);
    let snap = pool.shutdown();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.tune_stalls, 1, "exactly one online tune per key");
    assert_eq!(snap.plan_hits, 2, "every later request hits the registry");
    assert_eq!(registry.len(), 1, "the plan was published pool-wide");
}

#[test]
fn online_pool_converges_to_the_offline_plan() {
    let registry = TunedPlans::new();
    let pool = pool_with(registry.clone(), 2, 4, 2);
    pool.run_all(vec![online_kind(Precision::Int8)]).unwrap();
    pool.shutdown();
    let online = registry.get("tiny_online", Precision::Int8, &cfg()).unwrap();
    // Offline `repro tune` of the same workload with the same (default)
    // search options produces the identical plan — same per-op choices,
    // cycles, counts, and search breadth.
    let offline =
        tune_model(&cfg(), &tiny_model(), Precision::Int8, &TuneOptions::default())
            .unwrap();
    assert_eq!(*online, offline);
}

#[test]
fn per_request_stats_bit_identical_across_policies_and_worker_counts() {
    // The parity bar across Policy::{Mixed, Tuned, TunedOnline}: tuned
    // policies agree with each other bit for bit (whoever produced the
    // plan), both run exactly the static work (same MACs, same layers),
    // and are never slower; every policy's stats are invariant in worker
    // count and micro-batch cap.
    let prec = Precision::Int8;
    let run = |policy: Policy, registry: TunedPlans, workers: usize, max_batch: usize| {
        let pool = pool_with(registry, workers, max_batch, 2);
        let kinds = vec![
            RequestKind::Model { model: tiny_model(), prec, policy },
            small_op(Precision::Int4, 4),
            RequestKind::Model { model: tiny_model(), prec, policy },
        ];
        pool.run_all(kinds).unwrap()
    };
    let assert_same = |a: &[RequestResult], b: &[RequestResult], what: &str| {
        assert_eq!(stats_digest(a), stats_digest(b), "{what}");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.stats, y.stats, "{what}: request {}", x.id);
            assert_eq!(x.layers, y.layers, "{what}: request {}", x.id);
        }
    };

    // TunedOnline is worker-count- and batching-invariant.
    let online_1 = run(Policy::TunedOnline, TunedPlans::new(), 1, 1);
    let online_3 = run(Policy::TunedOnline, TunedPlans::new(), 3, 8);
    assert_same(&online_1, &online_3, "online: workers 1 vs 3");

    // Pre-seeded Policy::Tuned (the offline path) serves the identical
    // stats: online vs offline tuning is invisible to the request.
    let offline_plan =
        tune_model(&cfg(), &tiny_model(), prec, &TuneOptions::default()).unwrap();
    let seeded = TunedPlans::new();
    seeded.insert(offline_plan);
    let tuned = run(Policy::Tuned, seeded, 2, 4);
    assert_same(&online_1, &tuned, "online vs pre-seeded tuned");

    // Mixed runs the same work (identical MACs and layer counts) and is
    // never faster than the tuned mapping.
    let mixed = run(Policy::Mixed, TunedPlans::new(), 2, 1);
    for (t, m) in online_1.iter().zip(&mixed) {
        assert_eq!(t.stats.macs, m.stats.macs, "request {}", t.id);
        assert_eq!(t.layers, m.layers, "request {}", t.id);
        assert!(
            t.stats.cycles <= m.stats.cycles,
            "request {}: tuned {} > mixed {}",
            t.id,
            t.stats.cycles,
            m.stats.cycles
        );
    }
}

#[test]
fn tune_stall_on_one_worker_never_blocks_other_lanes() {
    // Two workers: the first request stalls worker A in a tuning search
    // while a stream of INT4 ops lands on the other lane (different
    // precision => different affinity lane). Liveness: everything
    // completes, exactly one stall is paid, and the op results are the
    // deterministic ones — the stall never leaks into another request's
    // stats.
    let registry = TunedPlans::new();
    let pool = pool_with(registry, 2, 1, 2);
    let mut tickets = vec![pool.submit(online_kind(Precision::Int8)).unwrap()];
    for i in 0..8 {
        tickets.push(pool.submit(small_op(Precision::Int4, 2 + (i % 3))).unwrap());
    }
    let results: Vec<RequestResult> =
        tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    assert_eq!(results.len(), 9);
    // Identical op requests report identical stats regardless of the
    // concurrent stall.
    assert_eq!(results[1].stats, results[4].stats);
    assert_eq!(results[2].stats, results[5].stats);
    let snap = pool.shutdown();
    assert_eq!(snap.completed, 9);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.in_flight, 0);
    assert_eq!(snap.tune_stalls, 1);
}

#[test]
fn distinct_precisions_tune_separately_and_coalesced_batches_stall_once() {
    // Two precisions of one model are two registry keys (two stalls); a
    // coalesced batch of same-key requests runs the search once for the
    // whole batch.
    let registry = TunedPlans::new();
    let pool = pool_with(registry.clone(), 1, 8, 2);
    let kinds = vec![
        online_kind(Precision::Int8),
        online_kind(Precision::Int8),
        online_kind(Precision::Int4),
        online_kind(Precision::Int8),
        online_kind(Precision::Int4),
    ];
    let results = pool.run_all(kinds).unwrap();
    // Same-precision requests are bit-identical however they were served.
    assert_eq!(results[0].stats, results[1].stats);
    assert_eq!(results[1].stats, results[3].stats);
    assert_eq!(results[2].stats, results[4].stats);
    let snap = pool.shutdown();
    assert_eq!(snap.completed, 5);
    assert_eq!(registry.len(), 2, "one plan per (model, precision)");
    assert_eq!(snap.tune_stalls, 2, "one stall per key");
    // Whatever coalescing happened, accounting is consistent: every
    // executed TunedOnline batch either stalled or hit.
    assert_eq!(snap.tune_stalls + snap.plan_hits, snap.batches);
}
