//! Encode → decode → re-encode round-trip properties for the full ISA
//! surface: every instruction form, every precision, every mode/width/dim
//! selector, across both representations (32-bit words via
//! `encode`/`decode`, and text via `disassemble`/`assemble`).
//!
//! Generated operands stay inside the representable ranges on purpose —
//! 12-bit `ADDI` immediates, 7-bit stage counts, 4-bit kernel fields —
//! because the property under test is faithfulness of the codecs, not
//! their rejection behavior (the unit suites cover rejection).

use speed_rvv::config::Precision;
use speed_rvv::isa::disasm::disassemble_program;
use speed_rvv::isa::{
    assemble, assemble_line, decode, disassemble, encode, Dim, Insn, LdMode, StrategyKind,
    Vtype, WidthSel,
};

/// xorshift64* PRNG — deterministic, no OS entropy.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u32 {
        (lo + self.next() % (hi - lo + 1)) as u32
    }

    fn reg(&mut self) -> u8 {
        self.range(0, 31) as u8
    }
}

const SEWS: [u32; 4] = [8, 16, 32, 64];
const WIDTHS: [WidthSel; 4] = [
    WidthSel::FromCfg,
    WidthSel::Explicit(Precision::Int4),
    WidthSel::Explicit(Precision::Int8),
    WidthSel::Explicit(Precision::Int16),
];

/// One random instruction with all fields inside representable ranges.
fn rand_insn(rng: &mut Rng) -> Insn {
    let imm12 = |rng: &mut Rng| rng.range(0, 4095) as i32 - 2048;
    match rng.range(0, 16) {
        0 => Insn::Addi { rd: rng.reg(), rs1: 0, imm: imm12(rng) },
        1 => Insn::Addi { rd: rng.reg(), rs1: rng.reg(), imm: imm12(rng) },
        2 => Insn::Vsetvli {
            rd: rng.reg(),
            rs1: rng.reg(),
            vtype: Vtype::new(SEWS[rng.range(0, 3) as usize]),
        },
        3 => Insn::Vle { vd: rng.reg(), rs1: rng.reg(), eew: SEWS[rng.range(0, 3) as usize] },
        4 => Insn::Vse { vs3: rng.reg(), rs1: rng.reg(), eew: SEWS[rng.range(0, 3) as usize] },
        5 => Insn::Vmacc { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        6 => Insn::Vmul { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        7 => Insn::Vadd { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        8 => Insn::Vsub { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        9 => Insn::Vmax { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        10 => Insn::Vmin { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        11 => Insn::Vsra { vd: rng.reg(), vs1: rng.reg(), vs2: rng.reg() },
        12 => Insn::Vmv { vd: rng.reg(), rs1: rng.reg() },
        13 => {
            let prec = Precision::ALL[rng.range(0, 2) as usize];
            let strat = StrategyKind::ALL[rng.range(0, 3) as usize];
            Insn::Vsacfg {
                rd: rng.reg(),
                zimm: Insn::pack_cfg(prec, rng.range(1, 15), strat),
                uimm: rng.range(0, 31) as u8,
            }
        }
        14 => Insn::VsacfgDim {
            rd: rng.reg(),
            rs1: rng.reg(),
            dim: Dim::ALL[rng.range(0, Dim::ALL.len() as u64 - 1) as usize],
        },
        15 => Insn::Vsald {
            vd: rng.reg(),
            rs1: rng.reg(),
            mode: [LdMode::Sequential, LdMode::Broadcast][rng.range(0, 1) as usize],
            width: WIDTHS[rng.range(0, 3) as usize],
        },
        _ => {
            let (vd, vs1, vs2) = (rng.reg(), rng.reg(), rng.reg());
            let stages = rng.range(1, 127) as u8;
            if rng.range(0, 1) == 0 {
                Insn::Vsam { vd, vs1, vs2, stages }
            } else {
                Insn::Vsac { vd, vs1, vs2, stages }
            }
        }
    }
}

#[test]
fn binary_roundtrip_over_random_instructions() {
    let mut rng = Rng::new(0x1517_B1B0);
    for trial in 0..4000u32 {
        let i = rand_insn(&mut rng);
        let word = encode(&i);
        let back = decode(word)
            .unwrap_or_else(|e| panic!("trial {trial}: decode({word:#010x}) of {i:?}: {e}"));
        assert_eq!(back, i, "trial {trial}: word {word:#010x}");
        // Re-encode: the codec must be a bijection on its image, not
        // merely a retraction (distinct words decoding to one insn would
        // pass a single roundtrip but corrupt stored programs).
        assert_eq!(encode(&back), word, "trial {trial}: re-encode diverged");
    }
}

#[test]
fn text_roundtrip_over_random_instructions() {
    let mut rng = Rng::new(0xD15A_53B1);
    for trial in 0..4000u32 {
        let i = rand_insn(&mut rng);
        let text = disassemble(&i);
        let back = assemble_line(&text)
            .unwrap_or_else(|e| panic!("trial {trial}: assemble('{text}'): {e}"));
        assert_eq!(back, i, "trial {trial}: text '{text}'");
    }
}

#[test]
fn program_text_roundtrip_reaches_a_fixed_point() {
    let mut rng = Rng::new(0xF1DE_0117);
    let prog: Vec<Insn> = (0..256).map(|_| rand_insn(&mut rng)).collect();
    let text = disassemble_program(&prog);
    let back = assemble(&text).expect("disassembly reassembles");
    assert_eq!(back, prog);
    // Second trip must be textually identical: the syntax is canonical.
    assert_eq!(disassemble_program(&back), text);
}

#[test]
fn every_form_roundtrips_in_both_representations() {
    let mut forms: Vec<Insn> = vec![
        Insn::Addi { rd: 31, rs1: 0, imm: 2047 },
        Insn::Addi { rd: 1, rs1: 2, imm: -2048 },
        Insn::Vmv { vd: 0, rs1: 31 },
        Insn::Vsam { vd: 8, vs1: 0, vs2: 4, stages: 127 },
        Insn::Vsac { vd: 16, vs1: 3, vs2: 5, stages: 1 },
    ];
    for sew in SEWS {
        forms.push(Insn::Vsetvli { rd: 0, rs1: 30, vtype: Vtype::new(sew) });
        forms.push(Insn::Vle { vd: 1, rs1: 29, eew: sew });
        forms.push(Insn::Vse { vs3: 8, rs1: 27, eew: sew });
    }
    let arith: [fn(u8, u8, u8) -> Insn; 7] = [
        |vd, vs1, vs2| Insn::Vmacc { vd, vs1, vs2 },
        |vd, vs1, vs2| Insn::Vmul { vd, vs1, vs2 },
        |vd, vs1, vs2| Insn::Vadd { vd, vs1, vs2 },
        |vd, vs1, vs2| Insn::Vsub { vd, vs1, vs2 },
        |vd, vs1, vs2| Insn::Vmax { vd, vs1, vs2 },
        |vd, vs1, vs2| Insn::Vmin { vd, vs1, vs2 },
        |vd, vs1, vs2| Insn::Vsra { vd, vs1, vs2 },
    ];
    for f in arith {
        forms.push(f(9, 10, 11));
    }
    for prec in Precision::ALL {
        for strat in StrategyKind::ALL {
            forms.push(Insn::Vsacfg {
                rd: 25,
                zimm: Insn::pack_cfg(prec, 15, strat),
                uimm: 31,
            });
        }
    }
    for dim in Dim::ALL {
        forms.push(Insn::VsacfgDim { rd: 0, rs1: 25, dim });
    }
    for mode in [LdMode::Sequential, LdMode::Broadcast] {
        for width in WIDTHS {
            forms.push(Insn::Vsald { vd: 4, rs1: 29, mode, width });
        }
    }
    for i in forms {
        let word = encode(&i);
        assert_eq!(decode(word).unwrap(), i, "binary: {i:?}");
        let text = disassemble(&i);
        assert_eq!(assemble_line(&text).unwrap(), i, "text: '{text}'");
    }
}
