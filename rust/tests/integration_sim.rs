//! Integration: the simulator executing hand-written assembly programs,
//! multi-operator sequences, runtime precision switching, and failure
//! injection across module boundaries (assembler → decoder → pipeline →
//! memory system).

use speed_rvv::compiler::{compile_op, execute_op, MemLayout};
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::isa::{assemble, encode, decode, StrategyKind};
use speed_rvv::models::ops::OpDesc;
use speed_rvv::sim::{Processor, SimError};

#[test]
fn assembled_text_runs_through_binary_encoding() {
    // Full toolchain path: text -> Insn -> 32-bit word -> Insn -> simulate.
    let src = r#"
        li       x1, 64
        vsetvli  x0, x1, e8
        li       x2, 0
        vle8.v   v1, (x2)
        vadd.vv  v2, v1, v1
        li       x3, 256
        vse8.v   v2, (x3)
    "#;
    let prog = assemble(src).unwrap();
    let words: Vec<u32> = prog.iter().map(encode).collect();
    let decoded: Vec<_> = words.iter().map(|w| decode(*w).unwrap()).collect();
    assert_eq!(decoded, prog);

    let mut p = Processor::new(SpeedConfig::reference(), 4096);
    p.mem.preload(0, &[3u8; 64]);
    let st = p.run(&decoded).unwrap();
    assert_eq!(st.insns_total, 7);
    assert!(st.cycles > 0);
    assert_eq!(st.traffic.input_read, 64);
}

#[test]
fn back_to_back_operators_share_the_machine() {
    // Two MMs on one processor: the clock telescopes, stats accumulate,
    // and the second operator's numerics are unaffected by the first.
    let cfg = SpeedConfig::reference();
    let mut p = Processor::new(cfg, 1 << 22);
    let op1 = OpDesc::mm(8, 8, 8, Precision::Int8);
    let op2 = OpDesc::mm(4, 4, 4, Precision::Int16);

    let lay1 = MemLayout::for_op(&op1, 1 << 20).unwrap();
    let a1: Vec<i32> = (0..64).map(|i| (i % 7) - 3).collect();
    let b1: Vec<i32> = (0..64).map(|i| (i % 5) - 2).collect();
    p.mem.preload_packed(lay1.in_addr, &a1, op1.prec);
    p.mem.preload_packed(lay1.w_addr, &b1, op1.prec);
    let c1 = compile_op(&op1, &cfg, StrategyKind::Mm, lay1, true).unwrap();
    p.set_plan(c1.plan);
    let mut st1 = speed_rvv::sim::SimStats::default();
    for seg in &c1.segments {
        st1.merge(&p.run(seg).unwrap());
    }

    // Second operator at a different precision (runtime VSACFG switch) and
    // a different memory region.
    let lay2 = MemLayout {
        in_addr: 0x100000,
        w_addr: 0x110000,
        out_addr: 0x120000,
        partial_addr: 0x130000,
    };
    let a2: Vec<i32> = (0..16).map(|i| i - 8).collect();
    let b2: Vec<i32> = (0..16).map(|i| 8 - i).collect();
    p.mem.preload_packed(lay2.in_addr, &a2, op2.prec);
    p.mem.preload_packed(lay2.w_addr, &b2, op2.prec);
    let c2 = compile_op(&op2, &cfg, StrategyKind::Mm, lay2, true).unwrap();
    p.set_plan(c2.plan);
    let mut st2 = speed_rvv::sim::SimStats::default();
    for seg in &c2.segments {
        st2.merge(&p.run(seg).unwrap());
    }

    assert_eq!(st1.macs, op1.total_macs());
    assert_eq!(st2.macs, op2.total_macs());
    // The precision switch was counted (8b -> 16b via VSACFG; the first
    // VSACFG matches the reset default and is not a switch).
    assert_eq!(p.ctrl.precision_switches, 1);
    // Lifetime stats accumulate both runs.
    assert_eq!(p.lifetime_stats().macs, op1.total_macs() + op2.total_macs());

    // Verify op2's numerics independently.
    let got = p.mem.inspect_i32(lay2.out_addr, 16);
    let mut want = vec![0i32; 16];
    for i in 0..4 {
        for k in 0..4 {
            for j in 0..4 {
                want[i * 4 + j] += a2[i * 4 + k] * b2[k * 4 + j];
            }
        }
    }
    assert_eq!(got, want);
}

#[test]
fn failure_injection_vrf_overflow() {
    let mut p = Processor::new(SpeedConfig::reference(), 1 << 16);
    // Broadcast 1024 bytes into a 512-byte register region.
    let prog = assemble(
        "li x1, 1024\nvsetvli x0, x1, e8\nli x2, 0\nvsald v1, (x2), bcast, w=8",
    )
    .unwrap();
    assert!(matches!(p.run(&prog).unwrap_err(), SimError::VrfOverflow { .. }));
}

#[test]
fn failure_injection_memory_bounds() {
    let mut p = Processor::new(SpeedConfig::reference(), 128);
    let prog =
        assemble("li x1, 64\nvsetvli x0, x1, e16\nli x2, 96\nvle16.v v1, (x2)").unwrap();
    assert!(matches!(p.run(&prog).unwrap_err(), SimError::MemOutOfRange { .. }));
}

#[test]
fn failure_injection_compute_without_plan() {
    let mut p = Processor::new(SpeedConfig::reference(), 4096);
    let prog = assemble("vsam v8, v0, v4, stages=5").unwrap();
    assert_eq!(p.run(&prog).unwrap_err(), SimError::NoPlan);
    let prog = assemble("vsac v8, v0, v4, stages=5").unwrap();
    assert_eq!(p.run(&prog).unwrap_err(), SimError::NoPlan);
}

#[test]
fn oversized_operator_rejected_at_layout() {
    let op = OpDesc::conv(512, 512, 224, 224, 3, 1, 1, Precision::Int16);
    assert!(MemLayout::for_op(&op, 1 << 20).is_err());
}

#[test]
fn dwcv_stride2_geometry_end_to_end() {
    // DWCV with stride 2 through the whole stack (the Fig. 10/11 operator).
    let cfg = SpeedConfig::reference();
    let op = OpDesc::dwcv(8, 13, 13, 3, 2, 1, Precision::Int8);
    let mut p = Processor::new(cfg, 1 << 22);
    let layout = MemLayout::for_op(&op, 1 << 22).unwrap();
    let x: Vec<i32> = (0..op.input_elems() as i32).map(|i| (i % 11) - 5).collect();
    let w: Vec<i32> = (0..op.weight_elems() as i32).map(|i| (i % 5) - 2).collect();
    p.mem.preload_packed(layout.in_addr, &x, op.prec);
    p.mem.preload_packed(layout.w_addr, &w, op.prec);
    let c = compile_op(&op, &cfg, StrategyKind::Ff, layout, true).unwrap();
    p.set_plan(c.plan);
    for seg in &c.segments {
        p.run(seg).unwrap();
    }
    // 13x13 stride-2 pad-1 -> 7x7 outputs per channel.
    assert_eq!(op.output_elems(), 8 * 7 * 7);
    let out = p.mem.inspect_i32(layout.out_addr, op.output_elems() as usize);
    // Spot-check one interior output against a hand computation.
    // out[c=0][oy=1][ox=1] covers input rows 1..4, cols 1..4 of channel 0.
    let mut want = 0i32;
    for ky in 0..3usize {
        for kx in 0..3usize {
            let iy = 2 * 1 + ky as i32 - 1;
            let ix = 2 * 1 + kx as i32 - 1;
            let xv = x[(iy * 13 + ix) as usize];
            want += xv * w[ky * 3 + kx];
        }
    }
    assert_eq!(out[7 + 1], want);
}

#[test]
fn timing_only_and_functional_agree_on_cycles() {
    // functional=true adds numerics but must not change the clock.
    let cfg = SpeedConfig::reference();
    let op = OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int8);
    let layout = MemLayout::for_op(&op, 1 << 22).unwrap();

    let mut p1 = Processor::new(cfg, 1 << 22);
    let (t_timing, _) = execute_op(&mut p1, &op, StrategyKind::Ffcs, layout, false).unwrap();

    let mut p2 = Processor::new(cfg, 1 << 22);
    let x: Vec<i32> = vec![1; op.input_elems() as usize];
    let w: Vec<i32> = vec![1; op.weight_elems() as usize];
    p2.mem.preload_packed(layout.in_addr, &x, op.prec);
    p2.mem.preload_packed(layout.w_addr, &w, op.prec);
    let (t_func, _) = execute_op(&mut p2, &op, StrategyKind::Ffcs, layout, true).unwrap();

    assert_eq!(t_timing.cycles, t_func.cycles);
    assert_eq!(t_timing.traffic.total(), t_func.traffic.total());
}
