//! Observability inertness contract (ISSUE 8 acceptance bar): attaching a
//! tracer changes *nothing* the machine reports — per-layer and aggregate
//! `SimStats`, per-request serving digests, and the cycle-attribution
//! breakdown are bit-identical with tracing on or off, in both execution
//! modes and at every trace level (insn-level tracing lazily expands
//! batch runs, so this doubles as the batch-vs-exact parity witness).
//! The breakdown itself telescopes exactly: its components sum to the
//! simulator's cycle count to the cycle.

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::models::zoo::{model_by_name, Model};
use speed_rvv::obs::{chrome_trace_json, ObsConfig, SpanCat, TraceLevel};
use speed_rvv::report::fig12::downscale;
use speed_rvv::runtime::json::{parse, Json};
use speed_rvv::serve::{stats_digest, Request, Scenario, ServeOptions, ServePool};
use speed_rvv::sim::ExecMode;
use speed_rvv::Engine;

fn tiny_model() -> Model {
    downscale(&model_by_name("mobilenetv2").unwrap(), 8)
}

/// The serve-layer scenario: small enough for the exact-mode leg, mixed
/// enough to exercise affinity routing and micro-batching.
const SCENARIO: &str = r#"{
    "name": "obs_inertness",
    "seed": 20250807,
    "requests": 8,
    "arrival": { "pattern": "burst", "size": 4 },
    "mix": [
        { "model": "mobilenetv2", "prec": 8, "weight": 2, "downscale": 8 },
        { "op": "mm", "m": 24, "k": 32, "n": 24, "prec": 16, "weight": 1 },
        { "op": "dwcv", "c": 8, "h": 12, "w": 12, "ksize": 3, "prec": 4,
          "weight": 1 }
    ]
}"#;

#[test]
fn tracing_leaves_engine_stats_bit_identical_in_both_modes() {
    let model = tiny_model();
    for mode in [ExecMode::Batch, ExecMode::Exact] {
        let mut plain = Engine::new(SpeedConfig::reference()).unwrap();
        plain.set_exec_mode(mode);
        let base = plain.session().run_model(&model, Precision::Int8).unwrap();

        // Every level, including Insn — which on the batch path lazily
        // expands stream runs to per-instruction stepping and must still
        // report bit-identical stats (batch/exact parity).
        for level in [TraceLevel::Op, TraceLevel::Run, TraceLevel::Insn] {
            let mut traced = Engine::new(SpeedConfig::reference()).unwrap();
            traced.set_exec_mode(mode);
            traced.set_obs(ObsConfig::tracing(level));
            let r = traced.session().run_model(&model, Precision::Int8).unwrap();
            assert_eq!(r.total, base.total, "{mode:?} {level:?}");
            assert_eq!(r.layers.len(), base.layers.len());
            for (a, b) in r.layers.iter().zip(&base.layers) {
                assert_eq!(a.stats, b.stats, "{mode:?} {level:?} {:?}", a.op);
            }
            // Attribution is tracer-independent too: same buckets on and
            // off (the breakdown accumulates whether or not anyone looks).
            assert_eq!(traced.breakdown(), plain.breakdown(), "{mode:?} {level:?}");
            assert!(traced.tracer().unwrap().span_count() > 0, "{mode:?} {level:?}");
        }
    }
}

#[test]
fn breakdown_components_sum_exactly_to_simulated_cycles() {
    let model = tiny_model();
    for mode in [ExecMode::Batch, ExecMode::Exact] {
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        engine.set_exec_mode(mode);
        let r = engine.session().run_model(&model, Precision::Int8).unwrap();
        let b = engine.breakdown();
        // The engine is fresh, so its lifetime attribution is exactly
        // this run's — and the monotone-frontier argument makes the sum
        // exact, not approximate.
        assert_eq!(b.total(), r.total.cycles, "{mode:?}: {b:?}");
        assert!(b.chain > 0, "{mode:?}: no systolic-chain cycles in {b:?}");
        assert!(b.load > 0, "{mode:?}: no load cycles in {b:?}");
    }
}

fn serve_results(
    reqs: &[Request],
    workers: usize,
    mode: ExecMode,
    obs: ObsConfig,
) -> Vec<speed_rvv::serve::RequestResult> {
    let pool = ServePool::new(
        SpeedConfig::reference(),
        ServeOptions {
            workers,
            capacity: 64,
            max_batch: 2,
            exec_mode: mode,
            obs,
            ..Default::default()
        },
    )
    .unwrap();
    pool.run_all(reqs.to_vec()).unwrap()
}

#[test]
fn serve_digest_is_tracer_invariant_across_workers_and_modes() {
    let sc = Scenario::from_json(SCENARIO).unwrap();
    let reqs = sc.generate(false).unwrap();
    let reference =
        serve_results(&reqs, 1, ExecMode::Batch, ObsConfig::off());
    let base_digest = stats_digest(&reference);

    for workers in [1usize, 3] {
        for mode in [ExecMode::Batch, ExecMode::Exact] {
            let traced = serve_results(
                &reqs,
                workers,
                mode,
                ObsConfig::tracing(TraceLevel::Op),
            );
            assert_eq!(
                stats_digest(&traced),
                base_digest,
                "workers {workers}, {mode:?}"
            );
            for (a, b) in reference.iter().zip(&traced) {
                assert_eq!(a.stats, b.stats, "workers {workers}, {mode:?}");
            }
        }
    }
}

#[test]
fn chrome_trace_is_wellformed_and_op_spans_partition_the_timeline() {
    let model = tiny_model();
    let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
    engine.set_obs(ObsConfig::tracing(TraceLevel::Segment));
    let r = engine.session().run_model(&model, Precision::Int8).unwrap();
    let tracer = engine.tracer().unwrap();
    assert_eq!(tracer.dropped(), 0);
    let spans = tracer.take_spans();
    assert!(!spans.is_empty());

    // The acceptance bar: op-span durations sum to the simulator's own
    // total — the trace claims exactly the cycles that were simulated.
    let op_sum: u64 =
        spans.iter().filter(|s| s.cat == SpanCat::Op).map(|s| s.dur).sum();
    assert_eq!(op_sum, r.total.cycles);
    let seg_sum: u64 = spans
        .iter()
        .filter(|s| s.cat == SpanCat::Segment)
        .map(|s| s.dur)
        .sum();
    assert_eq!(seg_sum, r.total.cycles, "segments partition ops exactly");

    let json = chrome_trace_json(&spans, &engine.counters().snapshot());
    let doc = parse(&json).unwrap();
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert_eq!(events.len(), spans.len());
    assert_eq!(
        doc.get("otherData")
            .and_then(|o| o.get("clock"))
            .and_then(Json::as_str),
        Some("virtual-cycles")
    );
}

#[test]
fn traces_are_bit_reproducible_run_to_run() {
    // The virtual clock has no wall-time dependence: two identical runs
    // serialize to byte-identical trace documents.
    let emit = || {
        let model = tiny_model();
        let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
        engine.set_obs(ObsConfig::tracing(TraceLevel::Run));
        engine.session().run_model(&model, Precision::Int4).unwrap();
        let spans = engine.tracer().unwrap().take_spans();
        chrome_trace_json(&spans, &engine.counters().snapshot())
    };
    assert_eq!(emit(), emit());
}
