//! Integration: the Rust PJRT runtime executes every AOT artifact and the
//! three-way golden agreement holds (JAX golden == PJRT == cycle sim).
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise so unit
//! CI without Python still passes).

use std::path::PathBuf;

use speed_rvv::runtime::{golden_check, golden_check_all, PjrtEngine};
use speed_rvv::runtime::artifacts::Golden;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn engine_opens_and_lists_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let engine = PjrtEngine::open(&dir).expect("open engine");
    assert!(engine.manifest().len() >= 10, "expected full artifact set");
    for name in ["mm_i4", "mm_i8", "mm_i16", "conv3x3_i8", "mnv2_block_i8", "vit_mlp_i8"] {
        assert!(engine.manifest().artifact(name).is_some(), "{name}");
    }
}

#[test]
fn every_artifact_passes_golden_check() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut engine = PjrtEngine::open(&dir).expect("open engine");
    let reports = golden_check_all(&mut engine, &dir).expect("golden checks");
    assert!(!reports.is_empty());
    for r in &reports {
        assert!(r.pjrt_ok, "{}: PJRT output != JAX golden", r.name);
        if let Some(ok) = r.sim_ok {
            assert!(ok, "{}: simulator output != PJRT output", r.name);
        }
        assert!(r.elems > 0);
    }
    // The single-operator artifacts must have exercised the simulator path.
    let sim_checked = reports.iter().filter(|r| r.sim_ok.is_some()).count();
    assert!(sim_checked >= 7, "only {sim_checked} sim cross-checks ran");
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut engine = PjrtEngine::open(&dir).expect("open engine");
    assert_eq!(engine.cached(), 0);
    golden_check(&mut engine, &dir, "mm_i8").unwrap();
    assert_eq!(engine.cached(), 1);
    golden_check(&mut engine, &dir, "mm_i8").unwrap();
    assert_eq!(engine.cached(), 1);
}

#[test]
fn execute_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut engine = PjrtEngine::open(&dir).expect("open engine");
    // mm_i8 wants (32,64) x (64,32); feed wrong sizes.
    assert!(engine.execute("mm_i8", &[vec![0; 4], vec![0; 4]]).is_err());
    assert!(engine.execute("mm_i8", &[vec![0; 32 * 64]]).is_err());
    assert!(engine.execute("definitely_not_there", &[]).is_err());
}

#[test]
fn requant_epilogue_matches_pjrt_artifact() {
    // Fourth leg of the golden agreement: the vector-ALU requantization
    // program (VADD/VSRA/VMIN/VMAX on the cycle simulator) must reproduce
    // the AOT-compiled requant_s7_i8 artifact bit-exactly.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut engine = PjrtEngine::open(&dir).expect("open engine");
    let art = engine.manifest().artifact("requant_s7_i8").expect("artifact").clone();
    let golden = Golden::load(&dir, &art).expect("golden");
    let pjrt_out = engine.execute("requant_s7_i8", &golden.inputs).expect("execute");

    use speed_rvv::config::SpeedConfig;
    use speed_rvv::coordinator::epilogue::requant_program;
    use speed_rvv::sim::Processor;
    let cfg = SpeedConfig::reference();
    let mut p = Processor::new(cfg, 1 << 20);
    let acc = &golden.inputs[0];
    for (i, &v) in acc.iter().enumerate() {
        p.mem.preload(0x100 + 4 * i as u64, &v.to_le_bytes());
    }
    let prog = requant_program(&cfg, acc.len() as u64, 7, 8, 0x100, 0x8000);
    p.run(&prog).expect("sim");
    let sim_out = p.mem.inspect_i32(0x8000, acc.len());
    assert_eq!(sim_out, pjrt_out, "vector-ALU epilogue != PJRT artifact");
    assert_eq!(sim_out, golden.output, "vector-ALU epilogue != JAX golden");
}
