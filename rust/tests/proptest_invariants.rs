//! Property-based invariants over randomized inputs.
//!
//! The deployment image vendors no proptest, so properties are exercised
//! with a deterministic xorshift generator over a few hundred cases each —
//! same spirit: every case is a *universal* statement about the system,
//! not an example.

use speed_rvv::compiler::{compile_op, execute_op, MemLayout};
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::dataflow;
use speed_rvv::isa::{self, Dim, Insn, LdMode, StrategyKind, Vtype, WidthSel};
use speed_rvv::models::ops::OpDesc;
use speed_rvv::sim::{elem, Processor};

/// Deterministic xorshift64* stream.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }

    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }

    fn operand(&mut self, p: Precision) -> i32 {
        let (lo, hi) = p.range();
        lo + (self.next() % (hi - lo + 1) as u64) as i32
    }
}

fn random_insn(rng: &mut Rng) -> Insn {
    let v = |rng: &mut Rng| rng.range(0, 31) as u8;
    match rng.range(0, 12) {
        0 => Insn::Addi { rd: v(rng), rs1: v(rng), imm: rng.range(0, 4094) as i32 - 2047 },
        1 => Insn::Vsetvli {
            rd: v(rng),
            rs1: v(rng),
            vtype: Vtype::new(*rng.pick(&[8, 16, 32, 64])),
        },
        2 => Insn::Vle { vd: v(rng), rs1: v(rng), eew: *rng.pick(&[8, 16, 32, 64]) },
        3 => Insn::Vse { vs3: v(rng), rs1: v(rng), eew: *rng.pick(&[8, 16, 32, 64]) },
        4 => Insn::Vmacc { vd: v(rng), vs1: v(rng), vs2: v(rng) },
        5 => Insn::Vmul { vd: v(rng), vs1: v(rng), vs2: v(rng) },
        6 => Insn::Vadd { vd: v(rng), vs1: v(rng), vs2: v(rng) },
        7 => Insn::Vmv { vd: v(rng), rs1: v(rng) },
        8 => {
            let prec = *rng.pick(&Precision::ALL);
            let k = rng.range(1, 15) as u32;
            let strat = *rng.pick(&StrategyKind::ALL);
            Insn::Vsacfg { rd: v(rng), zimm: Insn::pack_cfg(prec, k, strat), uimm: v(rng) & 0x1F }
        }
        9 => Insn::VsacfgDim { rd: v(rng), rs1: v(rng), dim: *rng.pick(&Dim::ALL) },
        10 => Insn::Vsald {
            vd: v(rng),
            rs1: v(rng),
            mode: *rng.pick(&[LdMode::Sequential, LdMode::Broadcast]),
            width: *rng.pick(&[
                WidthSel::FromCfg,
                WidthSel::Explicit(Precision::Int4),
                WidthSel::Explicit(Precision::Int8),
                WidthSel::Explicit(Precision::Int16),
            ]),
        },
        11 => Insn::Vsam { vd: v(rng), vs1: v(rng), vs2: v(rng), stages: rng.range(1, 127) as u8 },
        _ => Insn::Vsac { vd: v(rng), vs1: v(rng), vs2: v(rng), stages: rng.range(1, 127) as u8 },
    }
}

#[test]
fn prop_isa_binary_roundtrip() {
    let mut rng = Rng::new(42);
    for _ in 0..2000 {
        let i = random_insn(&mut rng);
        let back = isa::decode(isa::encode(&i)).unwrap_or_else(|e| panic!("{i:?}: {e}"));
        assert_eq!(back, i);
    }
}

#[test]
fn prop_isa_text_roundtrip() {
    let mut rng = Rng::new(7);
    for _ in 0..2000 {
        let i = random_insn(&mut rng);
        let text = isa::disasm::disassemble(&i);
        let back = isa::assemble_line(&text).unwrap_or_else(|e| panic!("'{text}': {e}"));
        assert_eq!(back, i, "via '{text}'");
    }
}

#[test]
fn prop_elem_pack_roundtrip() {
    let mut rng = Rng::new(9);
    for _ in 0..300 {
        let p = *rng.pick(&Precision::ALL);
        let n = rng.range(1, 100) as usize;
        let vals: Vec<i32> = (0..n).map(|_| rng.operand(p)).collect();
        let buf = elem::pack(&vals, p);
        assert_eq!(elem::unpack(&buf, n, p), vals);
        assert_eq!(buf.len() as u64, p.bytes_for(n as u64));
    }
}

fn random_op(rng: &mut Rng) -> OpDesc {
    let prec = *rng.pick(&Precision::ALL);
    match rng.range(0, 3) {
        0 => OpDesc::mm(
            rng.range(1, 24) as u32,
            rng.range(1, 48) as u32,
            rng.range(1, 24) as u32,
            prec,
        ),
        1 => {
            let k = *rng.pick(&[1u32, 3, 5]);
            OpDesc::conv(
                rng.range(1, 12) as u32,
                rng.range(1, 16) as u32,
                rng.range(k as u64, 14) as u32,
                rng.range(k as u64, 14) as u32,
                k,
                rng.range(1, 2) as u32,
                k / 2,
                prec,
            )
        }
        2 => OpDesc::pwcv(
            rng.range(1, 16) as u32,
            rng.range(1, 16) as u32,
            rng.range(1, 12) as u32,
            rng.range(1, 12) as u32,
            prec,
        ),
        _ => OpDesc::dwcv(
            rng.range(1, 12) as u32,
            rng.range(3, 14) as u32,
            rng.range(3, 14) as u32,
            3,
            rng.range(1, 2) as u32,
            1,
            prec,
        ),
    }
}

/// Every compiled operator, on every applicable strategy, accounts exactly
/// its MAC count, stays within structural limits, and moves at least the
/// obligatory traffic.
#[test]
fn prop_compiled_ops_account_macs_and_traffic() {
    let mut rng = Rng::new(1234);
    let cfg = SpeedConfig::reference();
    for case in 0..120 {
        let op = random_op(&mut rng);
        op.validate().unwrap();
        for strat in StrategyKind::ALL {
            if !dataflow::applicable(strat, &op) {
                continue;
            }
            let mut p = Processor::new(cfg, 1 << 24);
            let layout = MemLayout::for_op(&op, 1 << 24).unwrap();
            let (st, summary) = execute_op(&mut p, &op, strat, layout, false)
                .unwrap_or_else(|e| panic!("case {case} {op:?} {strat}: {e}"));
            assert_eq!(st.macs, op.total_macs(), "case {case} {op:?} {strat}");
            // MPTU busy time is bounded by the schedule size.
            assert!(
                st.fu_busy[2] <= summary.total_stages + 3 * summary.vsam,
                "case {case}: MPTU busy {} vs stages {}",
                st.fu_busy[2],
                summary.total_stages
            );
            // Obligatory traffic: outputs written once, something read.
            assert!(
                st.traffic.output_write >= op.output_bytes(),
                "case {case} {op:?} {strat}: outputs {}",
                st.traffic.output_write
            );
            assert!(st.traffic.reads() > 0, "case {case} {op:?} {strat}");
            // ops/cycle can never exceed the configuration's peak.
            assert!(
                st.ops_per_cycle() <= 2.0 * cfg.peak_macs_per_cycle(op.prec) as f64 + 1e-9,
                "case {case}: {} ops/cycle",
                st.ops_per_cycle()
            );
        }
    }
}

/// Functional property: the compiled MM stream computes exactly A·B for
/// random shapes, precisions and seeds.
#[test]
fn prop_mm_functional_correctness() {
    let mut rng = Rng::new(77);
    let cfg = SpeedConfig::reference();
    for _ in 0..40 {
        let prec = *rng.pick(&Precision::ALL);
        let (m, k, n) =
            (rng.range(1, 20) as usize, rng.range(1, 32) as usize, rng.range(1, 20) as usize);
        let op = OpDesc::mm(m as u32, k as u32, n as u32, prec);
        let a: Vec<i32> = (0..m * k).map(|_| rng.operand(prec)).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.operand(prec)).collect();

        let mut p = Processor::new(cfg, 1 << 22);
        let layout = MemLayout::for_op(&op, 1 << 22).unwrap();
        p.mem.preload_packed(layout.in_addr, &a, prec);
        p.mem.preload_packed(layout.w_addr, &b, prec);
        let c = compile_op(&op, &cfg, StrategyKind::Mm, layout, true).unwrap();
        p.set_plan(c.plan);
        for seg in &c.segments {
            p.run(seg).unwrap();
        }
        let got = p.mem.inspect_i32(layout.out_addr, m * n);
        let mut want = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * n + j] =
                        want[i * n + j].wrapping_add(a[i * k + kk].wrapping_mul(b[kk * n + j]));
                }
            }
        }
        assert_eq!(got, want, "mm {m}x{k}x{n} @{prec}");
    }
}

/// Functional property: all applicable strategies produce identical
/// numerics for the same convolution (the dataflow changes *when* bytes
/// move, never *what* is computed).
#[test]
fn prop_strategies_agree_functionally() {
    let mut rng = Rng::new(555);
    let cfg = SpeedConfig::reference();
    for _ in 0..25 {
        let op = loop {
            let op = random_op(&mut rng);
            if op.kind != speed_rvv::models::OpKind::Mm {
                break op;
            }
        };
        let x: Vec<i32> = (0..op.input_elems()).map(|_| rng.operand(op.prec)).collect();
        let w: Vec<i32> = (0..op.weight_elems()).map(|_| rng.operand(op.prec)).collect();
        let mut outs = Vec::new();
        for strat in StrategyKind::ALL {
            if !dataflow::applicable(strat, &op) {
                continue;
            }
            let mut p = Processor::new(cfg, 1 << 24);
            let layout = MemLayout::for_op(&op, 1 << 24).unwrap();
            p.mem.preload_packed(layout.in_addr, &x, op.prec);
            p.mem.preload_packed(layout.w_addr, &w, op.prec);
            let c = compile_op(&op, &cfg, strat, layout, true).unwrap();
            p.set_plan(c.plan);
            for seg in &c.segments {
                p.run(seg).unwrap();
            }
            outs.push((strat, p.mem.inspect_i32(layout.out_addr, op.output_elems() as usize)));
        }
        for pair in outs.windows(2) {
            assert_eq!(pair[0].1, pair[1].1, "{:?} vs {:?} on {op:?}", pair[0].0, pair[1].0);
        }
    }
}

/// Precision monotonicity: for any operator, lower precision never costs
/// more cycles (PP only grows) on SPEED.
#[test]
fn prop_precision_monotonicity() {
    let mut rng = Rng::new(31337);
    let cfg = SpeedConfig::reference();
    for _ in 0..40 {
        let base = random_op(&mut rng);
        let cycles = |prec: Precision| {
            let op = OpDesc { prec, ..base };
            let mut p = Processor::new(cfg, 1 << 24);
            let layout = MemLayout::for_op(&op, 1 << 24).unwrap();
            let (st, _) =
                execute_op(&mut p, &op, op.preferred_strategy(), layout, false).unwrap();
            st.cycles
        };
        let c16 = cycles(Precision::Int16);
        let c8 = cycles(Precision::Int8);
        let c4 = cycles(Precision::Int4);
        assert!(c8 <= c16, "{base:?}: 8b {c8} > 16b {c16}");
        assert!(c4 <= c8, "{base:?}: 4b {c4} > 8b {c8}");
    }
}

/// Kseg decomposition invariants: covers the kernel exactly, every piece
/// legal.
#[test]
fn prop_kseg_partition() {
    for k in 1..200u32 {
        let parts = dataflow::kseg_decompose(k);
        assert_eq!(parts.iter().sum::<u32>(), k);
        assert!(parts.iter().all(|&p| (1..=15).contains(&p)), "{k}: {parts:?}");
    }
}

/// Ara cost monotonicity in every dimension that only adds work.
#[test]
fn prop_ara_monotone() {
    use speed_rvv::ara::{ara_cost, AraParams};
    let mut rng = Rng::new(2024);
    let params = AraParams::default();
    for _ in 0..60 {
        let op = random_op(&mut rng);
        let base = ara_cost(&op, &params);
        assert!(base.cycles > 0 && base.insns > 0);
        // Doubling output channels (or M for MM) cannot reduce cost.
        let bigger = match op.kind {
            speed_rvv::models::OpKind::Mm => OpDesc { m: op.m * 2, ..op },
            speed_rvv::models::OpKind::Dwcv => OpDesc { c: op.c * 2, f: op.f * 2, ..op },
            _ => OpDesc { f: op.f * 2, ..op },
        };
        let b = ara_cost(&bigger, &params);
        assert!(b.cycles >= base.cycles, "{op:?}");
        assert!(b.dram_total() >= base.dram_total(), "{op:?}");
    }
}
