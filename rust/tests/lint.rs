//! Clean-codegen contract of the performance linter (`analysis::lint`).
//!
//! Every lint rule is designed so the operator compiler's own output
//! cannot fire it (the no-false-positive argument documented per rule in
//! the module). This test holds that promise across the whole model zoo —
//! every model, every precision, the default Sec. III mapping and the
//! auto-tuner's full candidate space — with **no allowlist**: zero
//! findings, or the rule (or the compiler) is wrong.
//!
//! The complementary direction — each rule *does* fire on a hand-mutated
//! stream — lives with the rules themselves (`src/analysis/lint.rs`
//! in-file tests, one per stable rule ID).

use speed_rvv::analysis::lint::lint_op;
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::dataflow::MappingChoice;
use speed_rvv::models::zoo::{model_by_name, MODELS};
use speed_rvv::models::OpDesc;
use speed_rvv::report::fig12::downscale;
use speed_rvv::tune::{candidates_for, TuneOptions};

/// The whole zoo at every precision under the default mixed mapping lints
/// clean (downscaled shapes — the same sweep `repro lint --all --quick`
/// runs in CI, restricted to the static mapping).
#[test]
fn zoo_default_mappings_lint_clean() {
    let cfg = SpeedConfig::reference();
    let mut programs = 0u32;
    for name in MODELS {
        let model = downscale(&model_by_name(name).unwrap(), 4);
        for prec in Precision::ALL {
            let m = model.at_precision(prec);
            let mut seen: Vec<OpDesc> = Vec::new();
            for op in &m.ops {
                if seen.contains(op) {
                    continue;
                }
                seen.push(*op);
                let rep = lint_op(op, &cfg, MappingChoice::preferred(op)).unwrap();
                assert!(
                    rep.is_clean(),
                    "{name} @ {prec} {op:?}: {:?}",
                    rep.findings
                );
                assert!(rep.insns > 0, "{name} @ {prec} {op:?}: empty stream");
                programs += 1;
            }
        }
    }
    assert!(programs > 50, "only {programs} programs swept");
}

/// The tuner's full (strategy × chunk) candidate space also lints clean —
/// chunked and re-strategized streams are still compiler output, so the
/// no-false-positive contract covers them too.
#[test]
fn tuner_candidate_space_lints_clean() {
    let cfg = SpeedConfig::reference();
    let topts = TuneOptions::default();
    for name in ["mobilenetv2", "vit_tiny"] {
        let model = downscale(&model_by_name(name).unwrap(), 4);
        for prec in Precision::ALL {
            let m = model.at_precision(prec);
            let mut seen: Vec<OpDesc> = Vec::new();
            for op in &m.ops {
                if seen.contains(op) {
                    continue;
                }
                seen.push(*op);
                for choice in candidates_for(op, &cfg, &topts) {
                    let rep = lint_op(op, &cfg, choice).unwrap();
                    assert!(
                        rep.is_clean(),
                        "{name} @ {prec} {op:?} {choice}: {:?}",
                        rep.findings
                    );
                }
            }
        }
    }
}
