//! Integration: the Engine/Session execution API — compiled-program
//! caching, precision-switch elision, typed errors, and parity with the
//! one-shot coordinator path.

use speed_rvv::compiler::MemLayout;
use speed_rvv::coordinator::{mem_requirement, run_model, Policy};
use speed_rvv::engine::Engine;
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::ops::OpDesc;
use speed_rvv::models::zoo::{model_by_name, Model};
use speed_rvv::report::fig12::downscale;
use speed_rvv::{Precision, SpeedConfig, SpeedError};

/// Quick-mode copy of a zoo model (1/4-scale feature maps).
fn downscaled(name: &str) -> Model {
    downscale(&model_by_name(name).unwrap(), 4)
}

#[test]
fn serving_loop_compiles_each_layer_exactly_once() {
    // The acceptance scenario: a model served repeatedly through one
    // engine compiles every (op, strategy, precision) program exactly once.
    let model = downscaled("mobilenetv2");
    let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
    let mut session = engine.session();
    let first = session.run_model(&model, Precision::Int8).unwrap();
    drop(session);
    let misses_after_first = engine.cache_stats().misses;
    let programs_after_first = engine.compiled_programs();
    assert!(misses_after_first > 0);

    // Five more "requests" for the same network.
    let mut session = engine.session();
    for _ in 0..5 {
        let r = session.run_model(&model, Precision::Int8).unwrap();
        // Cached replays stream the identical program: identical work and
        // traffic. (Cycles may differ by pipeline overlap at the pass
        // boundary, so they are not compared bit-exactly.)
        assert_eq!(r.total.macs, first.total.macs);
        assert_eq!(r.total.traffic, first.total.traffic);
        assert_eq!(r.total.insns_total, first.total.insns_total);
    }
    drop(session);
    let stats = engine.cache_stats();
    assert_eq!(stats.misses, misses_after_first, "zero recompilations while serving");
    assert_eq!(engine.compiled_programs(), programs_after_first);
    assert_eq!(stats.hits, 5 * misses_after_first, "every layer of every rerun was a hit");
    assert!(stats.hit_rate() > 0.8);
}

#[test]
fn precision_switches_are_elided_within_a_precision() {
    let model = downscaled("resnet18");
    let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
    let mut session = engine.session();
    // Datapath resets to INT8; an INT8 pass performs zero switches.
    session.run_model(&model, Precision::Int8).unwrap();
    assert_eq!(session.precision_switches(), 0);
    // 16-bit pass: one switch at the first layer, none after.
    session.run_model(&model, Precision::Int16).unwrap();
    assert_eq!(session.precision_switches(), 1);
    // Back-to-back 16-bit pass: still one.
    session.run_model(&model, Precision::Int16).unwrap();
    assert_eq!(session.precision_switches(), 1);
    // Per-layer stats carry the same information.
    let r = session.run_model(&model, Precision::Int4).unwrap();
    let layer_switches: u64 = r.layers.iter().map(|l| l.stats.precision_switches).sum();
    assert_eq!(layer_switches, 1, "only the first INT4 layer switches");
    assert_eq!(r.total.precision_switches, 1);
}

#[test]
fn engine_path_matches_one_shot_coordinator() {
    let model = downscaled("vit_tiny");
    let cfg = SpeedConfig::reference();
    for prec in [Precision::Int16, Precision::Int8] {
        let legacy = run_model(&model, prec, &cfg, Policy::Mixed).unwrap();
        let mut engine = Engine::with_memory(cfg, mem_requirement(&model)).unwrap();
        let fresh = engine.session().run_model(&model, prec).unwrap();
        assert_eq!(fresh.total.cycles, legacy.total.cycles, "{prec}");
        assert_eq!(fresh.total.traffic, legacy.total.traffic, "{prec}");
        assert_eq!(fresh.total.insns_total, legacy.total.insns_total, "{prec}");
    }
}

#[test]
fn typed_errors_are_matchable() {
    // Config class: invalid geometry is rejected before any simulation.
    let bad_cfg = SpeedConfig { tile_r: 3, ..SpeedConfig::reference() };
    assert!(matches!(Engine::new(bad_cfg), Err(SpeedError::Config(_))));

    // Compile class: strategy not applicable to the operator kind.
    let mut engine = Engine::new(SpeedConfig::reference()).unwrap();
    let dw = OpDesc::dwcv(8, 8, 8, 3, 1, 1, Precision::Int8);
    match engine.session().run_op(&dw, StrategyKind::Cf) {
        Err(SpeedError::Compile(msg)) => assert!(msg.contains("not applicable"), "{msg}"),
        other => panic!("expected Compile error, got {other:?}"),
    }

    // Layout class: operator larger than the provided memory.
    let big = OpDesc::conv(512, 512, 112, 112, 3, 1, 1, Precision::Int16);
    match MemLayout::for_op(&big, 1 << 20) {
        Err(e @ SpeedError::Layout(_)) => {
            assert_eq!(e.kind(), "layout");
            assert!(std::error::Error::source(&e).is_none());
        }
        other => panic!("expected Layout error, got {other:?}"),
    }
}

#[test]
fn mem_requirement_covers_every_benchmark_model() {
    // The sizing function and the placement function share constants; the
    // requirement must always admit every operator of the model.
    for name in speed_rvv::models::zoo::MODELS {
        let m = model_by_name(name).unwrap();
        let need = mem_requirement(&m);
        for op in &m.ops {
            assert!(MemLayout::for_op(op, need).is_ok(), "{name} {op:?}");
            assert!(MemLayout::required_bytes(op) <= need as u64, "{name} {op:?}");
        }
    }
}
