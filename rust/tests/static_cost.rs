//! Property contract of the static cost model (`analysis::cost`).
//!
//! The model claims **bit-exactness**: abstract-interpreting a compiled
//! stream must predict the same `SimStats` *and* the same `CycleBreakdown`
//! as actually executing it on a fresh machine — across random operator
//! shapes, all three precisions, and every feasible mapping candidate
//! (strategy × chunk, the auto-tuner's full search space). That equality
//! is what lets the tuner prune candidates without simulating them and
//! still produce a byte-identical plan.
//!
//! The deployment image vendors no proptest; properties are exercised with
//! a deterministic xorshift generator (same convention as
//! `fastpath_parity.rs`).

use speed_rvv::analysis::cost::cost_op;
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::models::ops::OpDesc;
use speed_rvv::sim::ExecMode;
use speed_rvv::tune::{candidates_for, TuneOptions};
use speed_rvv::Engine;

/// Deterministic xorshift64* stream.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }

    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() % xs.len() as u64) as usize]
    }
}

fn random_op(rng: &mut Rng) -> OpDesc {
    let prec = *rng.pick(&Precision::ALL);
    match rng.range(0, 3) {
        0 => OpDesc::mm(
            rng.range(1, 24) as u32,
            rng.range(1, 48) as u32,
            rng.range(1, 24) as u32,
            prec,
        ),
        1 => {
            let k = *rng.pick(&[1u32, 3, 5]);
            OpDesc::conv(
                rng.range(1, 12) as u32,
                rng.range(1, 16) as u32,
                rng.range(k as u64, 14) as u32,
                rng.range(k as u64, 14) as u32,
                k,
                rng.range(1, 2) as u32,
                k / 2,
                prec,
            )
        }
        2 => OpDesc::pwcv(
            rng.range(1, 16) as u32,
            rng.range(1, 16) as u32,
            rng.range(1, 12) as u32,
            rng.range(1, 12) as u32,
            prec,
        ),
        _ => OpDesc::dwcv(
            rng.range(1, 12) as u32,
            rng.range(3, 14) as u32,
            rng.range(3, 14) as u32,
            3,
            rng.range(1, 2) as u32,
            1,
            prec,
        ),
    }
}

/// Predicted cost == simulated cost, bit for bit, on a fresh engine in
/// batch mode (the tuner's oracle), for every feasible mapping candidate
/// of random operators at every precision.
#[test]
fn prop_static_cost_is_bit_exact_across_candidates() {
    let cfg = SpeedConfig::reference();
    let topts = TuneOptions::default(); // full (strategy x chunk) space
    let mut rng = Rng::new(0xC057);
    let mut checked = 0u32;
    for case in 0..40 {
        let op = random_op(&mut rng);
        for choice in candidates_for(&op, &cfg, &topts) {
            let predicted = cost_op(&op, &cfg, choice).unwrap();

            let mut engine = Engine::new(cfg).unwrap();
            engine.set_exec_mode(ExecMode::Batch);
            let (stats, _) = engine.run_op_with(&op, choice, false).unwrap();

            assert_eq!(
                predicted.stats, stats,
                "case {case} {op:?} {choice}: predicted stats diverge"
            );
            assert_eq!(
                predicted.breakdown,
                engine.breakdown(),
                "case {case} {op:?} {choice}: predicted breakdown diverges"
            );
            // The breakdown's own completeness invariant must hold for
            // the prediction too: every cycle is attributed.
            assert_eq!(predicted.breakdown.total(), predicted.stats.cycles);
            assert_eq!(predicted.cost(), (stats.cycles, stats.traffic.total()));
            checked += 1;
        }
    }
    assert!(checked > 100, "only {checked} (op, candidate) points checked");
}

/// The FF weight-spill boundary pair: F=604 (last VRF-resident) and
/// F=608 (first spilled) INT8 3x3 CONVs on the reference configuration.
/// The static cost model must stay bit-exact on both sides — the spilled
/// stream's per-row refetch runs are replayed like any other emitted
/// instructions — and the spill must be visible in the cost report.
#[test]
fn static_cost_is_bit_exact_across_the_ff_spill_boundary() {
    use speed_rvv::dataflow::{self, MappingChoice};
    use speed_rvv::isa::StrategyKind;
    let cfg = SpeedConfig::reference();
    for (f, spilled) in [(604u32, false), (608, true)] {
        let op = OpDesc::conv(8, f, 6, 6, 3, 1, 1, Precision::Int8);
        assert_eq!(
            dataflow::ff_weight_refetches(&op, &cfg, None) > 0,
            spilled,
            "F={f}: boundary moved"
        );
        let choice = MappingChoice::of(StrategyKind::Ff);
        let predicted = cost_op(&op, &cfg, choice).unwrap();
        let mut engine = Engine::new(cfg).unwrap();
        engine.set_exec_mode(ExecMode::Batch);
        let (stats, _) = engine.run_op_with(&op, choice, false).unwrap();
        assert_eq!(predicted.stats, stats, "F={f}: predicted stats diverge");
        assert_eq!(predicted.breakdown, engine.breakdown(), "F={f}");
        assert_eq!(predicted.cost(), (stats.cycles, stats.traffic.total()), "F={f}");
        // The refetch traffic is the declared spill, byte for byte.
        assert_eq!(
            stats.traffic.weight_read,
            op.prec
                .bytes_for(op.weight_elems() + dataflow::ff_weight_refetches(&op, &cfg, None)),
            "F={f}"
        );
    }
}

/// The prediction is also exact against per-instruction execution — the
/// cost model replays the scoreboard recurrence, so both simulator modes
/// must agree with it (they are bit-identical to each other by the
/// fast-path parity property).
#[test]
fn static_cost_matches_exact_mode_too() {
    let cfg = SpeedConfig::reference();
    for op in [
        OpDesc::mm(12, 48, 10, Precision::Int8),
        OpDesc::conv(8, 8, 10, 10, 3, 1, 1, Precision::Int16),
        OpDesc::pwcv(16, 16, 8, 8, Precision::Int4),
    ] {
        for choice in candidates_for(&op, &cfg, &TuneOptions::default()) {
            let predicted = cost_op(&op, &cfg, choice).unwrap();
            let mut engine = Engine::new(cfg).unwrap();
            engine.set_exec_mode(ExecMode::Exact);
            let (stats, _) = engine.run_op_with(&op, choice, false).unwrap();
            assert_eq!(predicted.stats, stats, "{op:?} {choice}");
            assert_eq!(predicted.breakdown, engine.breakdown(), "{op:?} {choice}");
        }
    }
}
