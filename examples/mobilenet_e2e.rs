//! End-to-end driver: full MobileNetV2 INT8 inference on SPEED.
//!
//! Exercises every layer of the stack on a real workload:
//!  1. a warm [`Engine`] lowers all 52 MobileNetV2 operators once through
//!     the operator compiler under the mixed dataflow policy (CF for PWCV,
//!     FF for DWCV, FFCS for the stem CONV, MM for the classifier) — the
//!     16/8/4-bit passes share one `Session`, so precision switches cost a
//!     single-cycle `VSACFG` each and repeat passes recompile nothing;
//!  2. the cycle simulator executes the cached programs (timing +
//!     byte-accurate traffic);
//!  3. the functional path is verified end-to-end: a quantized
//!     inverted-residual block (PWCV→DWCV→PWCV with requantization) is run
//!     operator-by-operator through the simulator and compared bit-exactly
//!     against the AOT-lowered JAX/Pallas artifact executed via PJRT;
//!  4. the Ara baseline runs the same network for the Table I comparison.
//!
//! ```sh
//! make artifacts && cargo run --release --example mobilenet_e2e
//! ```

use speed_rvv::ara::AraParams;
use speed_rvv::config::Precision;
use speed_rvv::coordinator::{ara_complete_cycles, run_model_ara};
use speed_rvv::engine::Engine;
use speed_rvv::metrics::{inference_energy_mj, speed_area, speed_power};
use speed_rvv::models::zoo::model_by_name;
use speed_rvv::runtime::{golden_check, PjrtEngine};
use speed_rvv::{SpeedConfig, SpeedError};

fn main() -> Result<(), SpeedError> {
    let cfg = SpeedConfig::reference();
    let model = model_by_name("mobilenetv2").expect("zoo");
    println!(
        "MobileNetV2 on SPEED ({} lanes x {}x{}, {:.2} GHz): {} vector operators, {:.2} GMACs\n",
        cfg.lanes,
        cfg.tile_r,
        cfg.tile_c,
        cfg.freq_ghz,
        model.ops.len(),
        model.total_macs() as f64 / 1e9
    );

    // ---- full-network inference at all three precisions through one
    //      warm engine ----------------------------------------------------
    println!("=== multi-precision inference (runtime VSACFG switching) ===");
    let mut engine = Engine::new(cfg)?;
    let mut session = engine.session();
    let mut int8_result = None;
    for prec in [Precision::Int16, Precision::Int8, Precision::Int4] {
        let r = session.run_model(&model, prec)?;
        let ms = r.vector_cycles() as f64 / (cfg.freq_ghz * 1e9) * 1e3;
        println!(
            "{prec}: {:>11} cycles ({:6.2} ms @ {:.2} GHz) | {:6.2} ops/cycle \
             ({:6.1} GOPS) | {:6.1} MiB DRAM | {:.1} mJ",
            r.vector_cycles(),
            ms,
            cfg.freq_ghz,
            r.ops_per_cycle(),
            r.gops(cfg.freq_ghz),
            r.total.traffic.total() as f64 / (1 << 20) as f64,
            inference_energy_mj(&cfg, r.vector_cycles(), r.total.traffic.total()),
        );
        if prec == Precision::Int8 {
            int8_result = Some(r);
        }
    }
    let switches = session.precision_switches();
    drop(session);
    let cache = engine.cache_stats();
    println!(
        "engine: {} programs compiled once, {} cache hits, {} datapath \
         precision switches across the three passes",
        engine.compiled_programs(),
        cache.hits,
        switches
    );
    let int8 = int8_result.unwrap();

    // ---- per-strategy layer breakdown -----------------------------------
    println!("\n=== INT8 layer breakdown by dataflow strategy ===");
    for strat in [
        speed_rvv::isa::StrategyKind::Ffcs,
        speed_rvv::isa::StrategyKind::Cf,
        speed_rvv::isa::StrategyKind::Ff,
        speed_rvv::isa::StrategyKind::Mm,
    ] {
        let layers: Vec<_> = int8.layers.iter().filter(|l| l.strat == strat).collect();
        if layers.is_empty() {
            continue;
        }
        let cycles: u64 = layers.iter().map(|l| l.stats.cycles).sum();
        println!(
            "  {:>4}: {:2} layers, {:>10} cycles ({:4.1}% of total)",
            strat.to_string().to_uppercase(),
            layers.len(),
            cycles,
            100.0 * cycles as f64 / int8.vector_cycles() as f64
        );
    }

    // ---- Ara baseline (Table I) ------------------------------------------
    let ara = run_model_ara(&model, Precision::Int8, &AraParams::default());
    println!("\n=== Table I comparison (INT8) ===");
    println!(
        "  SPEED conv-only {:>11} cycles | complete {:>11} cycles",
        int8.vector_cycles(),
        int8.complete_cycles()
    );
    println!(
        "  Ara   conv-only {:>11} cycles | complete {:>11} cycles",
        ara.cycles,
        ara_complete_cycles(&ara, &int8)
    );
    println!(
        "  speedup: {:.2}x conv-only (paper 144.25x), {:.2}x complete (paper 100.81x)",
        ara.cycles as f64 / int8.vector_cycles() as f64,
        ara_complete_cycles(&ara, &int8) as f64 / int8.complete_cycles() as f64
    );

    // ---- functional verification against the JAX/Pallas golden model ----
    println!("\n=== functional verification (inverted-residual block) ===");
    match PjrtEngine::open("artifacts") {
        Ok(mut pjrt) => {
            // The composite block (PWCV -> DWCV -> PWCV with requantization)
            // against the build-time golden vector...
            let r = golden_check(&mut pjrt, std::path::Path::new("artifacts"),
                                 "mnv2_block_i8")?;
            if !r.pjrt_ok {
                return Err(SpeedError::Artifact(
                    "PJRT output != JAX golden for mnv2_block_i8".into(),
                ));
            }
            println!("  mnv2_block_i8: PJRT == JAX golden ({} elems) ✔", r.elems);
            // ...and the individual operator classes three ways (golden ==
            // PJRT == cycle simulator).
            for name in ["pwconv_i8", "dwconv3x3_s2_i8", "conv3x3_i8"] {
                let r = golden_check(&mut pjrt, std::path::Path::new("artifacts"), name)?;
                if !r.ok() {
                    return Err(SpeedError::Artifact(format!("{name} failed")));
                }
                println!(
                    "  {name}: JAX golden == PJRT == simulator ({} elems) ✔",
                    r.elems
                );
            }
        }
        Err(_) => println!("  (artifacts not built — run `make artifacts`)"),
    }

    // ---- deployment summary ---------------------------------------------
    let area = speed_area(&cfg);
    println!(
        "\ninstance: {:.2} mm² @ 28 nm, {:.0} mW -> {:.1} inf/s INT8, {:.1} GOPS/W",
        area.total(),
        speed_power(&cfg) * 1e3,
        cfg.freq_ghz * 1e9 / int8.complete_cycles() as f64,
        int8.gops(cfg.freq_ghz) / speed_power(&cfg)
    );
    Ok(())
}
