//! Quickstart: assemble a SPEED program, run an operator through the
//! Engine/Session API, and verify the numerics against the AOT-compiled
//! JAX artifact via PJRT.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use speed_rvv::config::Precision;
use speed_rvv::engine::Engine;
use speed_rvv::isa::{assemble, StrategyKind};
use speed_rvv::models::ops::OpDesc;
use speed_rvv::runtime::PjrtEngine;
use speed_rvv::{SpeedConfig, SpeedError};

fn main() -> Result<(), SpeedError> {
    // ---- 1. The hardware: the paper's reference instance, via the
    //         validated builder. --------------------------------------
    let cfg = SpeedConfig::builder().lanes(4).tile(2, 2).vrf_kib(16).build()?;
    println!(
        "SPEED: {} lanes x {}x{} MPTU @ {:.2} GHz (peak {:.1} GOPS @INT8)\n",
        cfg.lanes,
        cfg.tile_r,
        cfg.tile_c,
        cfg.freq_ghz,
        cfg.peak_gops(Precision::Int8)
    );

    // ---- 2. Hand-written vector assembly, straight from Fig. 2. --------
    let src = r#"
        li         x1, 16
        vsetvli    x0, x1, e8
        vsacfg     x2, prec=8, k=1, strat=mm
        li         x3, 0
        vsald      v0, (x3), seq, w=cfg     # inputs, lane-striped
        li         x4, 0x100
        vsald      v4, (x4), bcast, w=cfg   # weights, multi-broadcast
        vsam       v8, v0, v4, stages=4
    "#;
    let prog = assemble(src)?;
    println!("assembled {} instructions (Fig. 2 style stream)", prog.len());

    // ---- 3. A real operator through the engine's program cache. --------
    // 32x64 @ 64x32 INT8 matrix multiply — the same computation as the
    // `mm_i8` AOT artifact.
    let op = OpDesc::mm(32, 64, 32, Precision::Int8);
    let mut engine = Engine::new(cfg)?;
    let program = engine.program(&op, StrategyKind::Mm)?;
    let layout = *program.layout();
    println!(
        "compiled MM operator: {} insns ({} VSAM bursts, {} stages, {} vregs)",
        program.summary().total_insns,
        program.summary().vsam,
        program.summary().total_stages,
        program.summary().vregs_used
    );

    // Deterministic INT8 operands.
    let a: Vec<i32> = (0..32 * 64).map(|i| (i % 17) - 8).collect();
    let b: Vec<i32> = (0..64 * 32).map(|i| (i % 13) - 6).collect();
    engine.preload_packed(layout.in_addr, &a, op.prec);
    engine.preload_packed(layout.w_addr, &b, op.prec);

    // The session re-requests the same program: a cache hit, zero recompile.
    let layer = engine.session().with_functional(true).run_op(&op, StrategyKind::Mm)?;
    let sim_out = engine.inspect_i32(layout.out_addr, op.output_elems() as usize);
    println!(
        "simulated: {} cycles, {:.2} ops/cycle ({:.1} GOPS), {:.1} KiB DRAM traffic",
        layer.stats.cycles,
        layer.stats.ops_per_cycle(),
        layer.stats.gops(cfg.freq_ghz),
        layer.stats.traffic.total() as f64 / 1024.0
    );
    let cache = engine.cache_stats();
    println!(
        "program cache: {} hit(s), {} miss(es) — the session reused the compile",
        cache.hits, cache.misses
    );

    // ---- 4. Golden check against the JAX/Pallas artifact via PJRT. -----
    match PjrtEngine::open("artifacts") {
        Ok(mut pjrt) => {
            let hlo_out = pjrt.execute("mm_i8", &[a, b])?;
            assert_eq!(sim_out, hlo_out, "simulator disagrees with the HLO artifact!");
            println!("golden check: simulator == AOT HLO artifact ({} elems) ✔", hlo_out.len());
        }
        Err(_) => println!("(artifacts not built — run `make artifacts` for the golden check)"),
    }
    Ok(())
}
