//! Quickstart: assemble a SPEED program, run it on the cycle simulator,
//! and verify the numerics against the AOT-compiled JAX artifact via PJRT.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use speed_rvv::compiler::{compile_op, MemLayout};
use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::isa::{assemble, StrategyKind};
use speed_rvv::models::ops::OpDesc;
use speed_rvv::runtime::Engine;
use speed_rvv::sim::Processor;

fn main() -> anyhow::Result<()> {
    // ---- 1. The hardware: the paper's reference instance. --------------
    let cfg = SpeedConfig::reference();
    println!(
        "SPEED: {} lanes x {}x{} MPTU @ {:.2} GHz (peak {:.1} GOPS @INT8)\n",
        cfg.lanes,
        cfg.tile_r,
        cfg.tile_c,
        cfg.freq_ghz,
        cfg.peak_gops(Precision::Int8)
    );

    // ---- 2. Hand-written vector assembly, straight from Fig. 2. --------
    let src = r#"
        li         x1, 16
        vsetvli    x0, x1, e8
        vsacfg     x2, prec=8, k=1, strat=mm
        li         x3, 0
        vsald      v0, (x3), seq, w=cfg     # inputs, lane-striped
        li         x4, 0x100
        vsald      v4, (x4), bcast, w=cfg   # weights, multi-broadcast
        vsam       v8, v0, v4, stages=4
    "#;
    let prog = assemble(src).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("assembled {} instructions (Fig. 2 style stream)", prog.len());

    // ---- 3. A real operator through the operator compiler. -------------
    // 32x64 @ 64x32 INT8 matrix multiply — the same computation as the
    // `mm_i8` AOT artifact.
    let op = OpDesc::mm(32, 64, 32, Precision::Int8);
    let mem = 1 << 22;
    let layout = MemLayout::for_op(&op, mem).map_err(anyhow::Error::msg)?;
    let compiled =
        compile_op(&op, &cfg, StrategyKind::Mm, layout, true).map_err(anyhow::Error::msg)?;
    println!(
        "compiled MM operator: {} insns ({} VSAM bursts, {} stages, {} vregs)",
        compiled.summary.total_insns,
        compiled.summary.vsam,
        compiled.summary.total_stages,
        compiled.summary.vregs_used
    );

    // Deterministic INT8 operands.
    let a: Vec<i32> = (0..32 * 64).map(|i| (i % 17) - 8).collect();
    let b: Vec<i32> = (0..64 * 32).map(|i| (i % 13) - 6).collect();

    let mut proc = Processor::new(cfg, mem);
    proc.mem.preload_packed(layout.in_addr, &a, op.prec);
    proc.mem.preload_packed(layout.w_addr, &b, op.prec);
    proc.set_plan(compiled.plan);
    let mut stats = speed_rvv::sim::SimStats::default();
    for seg in &compiled.segments {
        stats.merge(&proc.run(seg).map_err(|e| anyhow::anyhow!("{e}"))?);
    }
    let sim_out = proc.mem.inspect_i32(layout.out_addr, op.output_elems() as usize);
    println!(
        "simulated: {} cycles, {:.2} ops/cycle ({:.1} GOPS), {:.1} KiB DRAM traffic",
        stats.cycles,
        stats.ops_per_cycle(),
        stats.gops(cfg.freq_ghz),
        stats.traffic.total() as f64 / 1024.0
    );

    // ---- 4. Golden check against the JAX/Pallas artifact via PJRT. -----
    match Engine::open("artifacts") {
        Ok(mut engine) => {
            let hlo_out = engine.execute("mm_i8", &[a, b])?;
            assert_eq!(sim_out, hlo_out, "simulator disagrees with the HLO artifact!");
            println!("golden check: simulator == AOT HLO artifact ({} elems) ✔", hlo_out.len());
        }
        Err(_) => println!("(artifacts not built — run `make artifacts` for the golden check)"),
    }
    Ok(())
}
