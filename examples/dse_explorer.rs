//! DSE explorer: walk the Fig. 14 design space interactively and print the
//! throughput / area-efficiency frontier, plus what the analytical models
//! say about each point's area, power and peak efficiency at all three
//! precisions. Each point is evaluated through its own `Engine` (see
//! `speed_rvv::dse::eval_point`).
//!
//! ```sh
//! cargo run --release --example dse_explorer [-- <lanes> <tile_r> <tile_c>]
//! cargo run --release --example dse_explorer -- --quick --workers 4
//! ```

use speed_rvv::config::{Precision, SpeedConfig};
use speed_rvv::coordinator::runner::default_workers;
use speed_rvv::dse::{dse_workload, eval_point, peak_area_eff, sweep_with};
use speed_rvv::metrics::{speed_area, speed_power};

fn describe(cfg: &SpeedConfig) {
    let area = speed_area(cfg);
    let power = speed_power(cfg);
    println!(
        "config {}L {}x{}: {} PEs, {:.2} mm² (lanes {:.0}%), {:.0} mW",
        cfg.lanes,
        cfg.tile_r,
        cfg.tile_c,
        cfg.total_pes(),
        area.total(),
        100.0 * area.lane_fraction(),
        power * 1e3
    );
    for p in Precision::ALL {
        println!(
            "  {p}: theoretical peak {:7.1} GOPS -> {:6.1} GOPS/mm², {:7.0} GOPS/W",
            cfg.peak_gops(p),
            cfg.peak_gops(p) / area.total(),
            cfg.peak_gops(p) / power
        );
    }
    let pt = eval_point(cfg, &dse_workload()).expect("sim");
    println!(
        "  measured on CONV3x3 @16-bit: {:.1} GOPS achieved ({:.0}% of peak), \
         {:.1} GOPS/mm²",
        pt.gops,
        100.0 * pt.gops / cfg.peak_gops(Precision::Int16),
        pt.area_eff()
    );
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let quick = raw.iter().any(|a| a == "--quick");
    let workers = raw
        .iter()
        .position(|a| a == "--workers")
        .and_then(|i| raw.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(default_workers);
    // Positional lanes/tile_r/tile_c — with flag tokens (and the value
    // following --workers) stripped so they cannot leak into the triple.
    let mut args: Vec<u32> = Vec::new();
    let mut skip_value = false;
    for a in &raw {
        if skip_value {
            skip_value = false;
            continue;
        }
        if a == "--workers" {
            skip_value = true;
            continue;
        }
        if a.starts_with("--") {
            continue;
        }
        if let Ok(v) = a.parse() {
            args.push(v);
        }
    }
    if args.len() == 3 && !quick {
        let cfg = SpeedConfig::dse(args[0], args[1], args[2]);
        if let Err(e) = cfg.validate() {
            eprintln!("invalid configuration: {e}");
            std::process::exit(1);
        }
        describe(&cfg);
        return;
    }

    println!("Fig. 14 design space: lanes x TILE_R x TILE_C in {{2,4,8}}³\n");
    let points = sweep_with(workers, quick);
    println!("{:<10} {:>8} {:>9} {:>10}", "config", "GOPS", "area mm²", "GOPS/mm²");
    for p in &points {
        println!(
            "{:<10} {:>8.1} {:>9.2} {:>10.1}",
            format!("{}L {}x{}", p.cfg.lanes, p.cfg.tile_r, p.cfg.tile_c),
            p.gops,
            p.area_mm2,
            p.area_eff()
        );
    }
    let peak = peak_area_eff(&points);
    println!(
        "\npeak area efficiency: {:.1} GOPS/mm² at {:.1} GOPS ({}L {}x{}) — \
         the paper reports the 4-lane instances as the efficiency sweet spot\n",
        peak.area_eff(),
        peak.gops,
        peak.cfg.lanes,
        peak.cfg.tile_r,
        peak.cfg.tile_c
    );
    describe(&peak.cfg);
}
