//! Transformer serving: batched ViT MLP blocks through the PJRT hot path.
//!
//! Demonstrates the production runtime topology: Python never runs — the
//! server loads the AOT-compiled `vit_mlp_i8` artifact once, then serves a
//! stream of requests against it, while a warm SPEED [`Engine`] predicts
//! what the same workload costs on silicon. Both sides are compile-once /
//! execute-many: the PJRT executable cache on the functional path, the
//! engine's program cache on the simulated path (the second and later
//! blocks replay cached instruction streams — zero recompilation).
//!
//! ```sh
//! make artifacts && cargo run --release --example vit_serving
//! ```

use std::time::Instant;

use speed_rvv::config::Precision;
use speed_rvv::engine::Engine;
use speed_rvv::isa::StrategyKind;
use speed_rvv::models::ops::OpDesc;
use speed_rvv::runtime::Engine as PjrtEngine;
use speed_rvv::{SpeedConfig, SpeedError};

const REQUESTS: usize = 64;

fn main() -> Result<(), SpeedError> {
    let mut pjrt = match PjrtEngine::open("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let art = pjrt
        .manifest()
        .artifact("vit_mlp_i8")
        .expect("vit_mlp_i8 in manifest")
        .clone();
    println!(
        "serving vit_mlp_i8: x{:?} @ w1{:?} / w2{:?} (INT8, requantized)",
        art.input_shapes[0], art.input_shapes[1], art.input_shapes[2]
    );

    // Fixed weights (loaded once, like a deployed model) + per-request
    // activations.
    let n_of = |s: &[i64]| s.iter().product::<i64>() as usize;
    let w1: Vec<i32> = (0..n_of(&art.input_shapes[1])).map(|i| (i as i32 % 11) - 5).collect();
    let w2: Vec<i32> = (0..n_of(&art.input_shapes[2])).map(|i| (i as i32 % 7) - 3).collect();

    // Warm the executable cache (compile once).
    let x0: Vec<i32> = vec![1; n_of(&art.input_shapes[0])];
    let _ = pjrt.execute("vit_mlp_i8", &[x0.clone(), w1.clone(), w2.clone()])?;

    let t0 = Instant::now();
    let mut checksum = 0i64;
    for req in 0..REQUESTS {
        let x: Vec<i32> = (0..n_of(&art.input_shapes[0]))
            .map(|i| (((i + req * 31) % 23) as i32) - 11)
            .collect();
        let y = pjrt.execute("vit_mlp_i8", &[x, w1.clone(), w2.clone()])?;
        checksum = checksum.wrapping_add(y.iter().map(|&v| v as i64).sum::<i64>());
    }
    let dt = t0.elapsed();
    println!(
        "PJRT hot path: {REQUESTS} requests in {:.1} ms -> {:.0} req/s \
         (p50 latency {:.2} ms/batch, checksum {checksum})",
        dt.as_secs_f64() * 1e3,
        REQUESTS as f64 / dt.as_secs_f64(),
        dt.as_secs_f64() * 1e3 / REQUESTS as f64
    );

    // ---- what the same block costs on SPEED silicon ----------------------
    let cfg = SpeedConfig::reference();
    let tokens = art.input_shapes[0][0] as u32;
    let d = art.input_shapes[0][1] as u32;
    let hidden = art.input_shapes[1][1] as u32;
    let mm1 = OpDesc::mm(tokens, d, hidden, Precision::Int8);
    let mm2 = OpDesc::mm(tokens, hidden, d, Precision::Int8);
    let mut engine = Engine::new(cfg)?;
    let mut session = engine.session();
    // First block compiles both MMs; every subsequent block is pure cache
    // hits — the serving steady state.
    let mut cycles = 0u64;
    for blk in 0..3 {
        cycles = 0;
        for op in [mm1, mm2] {
            cycles += session.run_op(&op, StrategyKind::Mm)?.stats.cycles;
        }
        let cache = session.engine().cache_stats();
        println!(
            "block {blk}: {cycles} cycles ({} compiled programs, {} hits / {} misses)",
            session.engine().compiled_programs(),
            cache.hits,
            cache.misses
        );
    }
    println!(
        "SPEED silicon estimate: {cycles} cycles/block ({:.2} µs @ {:.2} GHz, \
         {:.0}k blocks/s)",
        cycles as f64 / (cfg.freq_ghz * 1e9) * 1e6,
        cfg.freq_ghz,
        cfg.freq_ghz * 1e9 / cycles as f64 / 1e3
    );
    Ok(())
}
