//! Transformer serving: batched ViT MLP blocks through the PJRT hot path
//! and the `speed_rvv::serve` pool — the canonical serving demo.
//!
//! Demonstrates the production runtime topology: Python never runs — the
//! server loads the AOT-compiled `vit_mlp_i8` artifact once, then serves a
//! stream of requests against it, while a [`ServePool`] of warm SPEED
//! engines predicts what the same concurrent workload costs on silicon.
//! Both sides are compile-once / execute-many: the PJRT executable cache
//! on the functional path, the pool-shared program cache on the simulated
//! path. The weights are loaded once and passed by reference on every
//! request (`execute_slices`) — cloning them per request would distort
//! the serving measurement.
//!
//! ```sh
//! make artifacts && cargo run --release --example vit_serving
//! ```

use std::time::Instant;

use speed_rvv::config::Precision;
use speed_rvv::models::ops::OpDesc;
use speed_rvv::models::zoo::Model;
use speed_rvv::runtime::PjrtEngine;
use speed_rvv::serve::{Request, ServeOptions};
use speed_rvv::{ServePool, SpeedConfig, SpeedError};

const REQUESTS: usize = 64;

fn main() -> Result<(), SpeedError> {
    // ViT-Tiny MLP dimensions; overwritten by the artifact manifest when
    // the AOT outputs are present.
    let (mut tokens, mut d, mut hidden) = (197u32, 192u32, 768u32);
    match PjrtEngine::open("artifacts") {
        Ok(mut pjrt) => {
            let art = pjrt
                .manifest()
                .artifact("vit_mlp_i8")
                .expect("vit_mlp_i8 in manifest")
                .clone();
            println!(
                "serving vit_mlp_i8: x{:?} @ w1{:?} / w2{:?} (INT8, requantized)",
                art.input_shapes[0], art.input_shapes[1], art.input_shapes[2]
            );
            tokens = art.input_shapes[0][0] as u32;
            d = art.input_shapes[0][1] as u32;
            hidden = art.input_shapes[1][1] as u32;

            // Fixed weights: loaded once, like a deployed model, and
            // passed by slice on every request. Only the activations are
            // per-request.
            let n_of = |s: &[i64]| s.iter().product::<i64>() as usize;
            let w1: Vec<i32> =
                (0..n_of(&art.input_shapes[1])).map(|i| (i as i32 % 11) - 5).collect();
            let w2: Vec<i32> =
                (0..n_of(&art.input_shapes[2])).map(|i| (i as i32 % 7) - 3).collect();

            // Warm the executable cache (compile once).
            let x0: Vec<i32> = vec![1; n_of(&art.input_shapes[0])];
            let _ = pjrt.execute_slices("vit_mlp_i8", &[&x0, &w1, &w2])?;

            let t0 = Instant::now();
            let mut checksum = 0i64;
            for req in 0..REQUESTS {
                let x: Vec<i32> = (0..n_of(&art.input_shapes[0]))
                    .map(|i| (((i + req * 31) % 23) as i32) - 11)
                    .collect();
                let y = pjrt.execute_slices("vit_mlp_i8", &[&x, &w1, &w2])?;
                checksum = checksum.wrapping_add(y.iter().map(|&v| v as i64).sum::<i64>());
            }
            let dt = t0.elapsed();
            println!(
                "PJRT hot path: {REQUESTS} requests in {:.1} ms -> {:.0} req/s \
                 (p50 latency {:.2} ms/batch, checksum {checksum})",
                dt.as_secs_f64() * 1e3,
                REQUESTS as f64 / dt.as_secs_f64(),
                dt.as_secs_f64() * 1e3 / REQUESTS as f64
            );
        }
        Err(e) => {
            eprintln!(
                "artifacts not built ({e}); run `make artifacts` — \
                 serving the SPEED simulation side only"
            );
        }
    }

    // ---- what the same serving workload costs on SPEED silicon ----------
    // The MLP block as a two-layer model, served through a pool of warm
    // engines: the first request compiles both MMs (shared pool-wide),
    // every later one replays from cache, and identical concurrent
    // requests coalesce into micro-batches.
    let cfg = SpeedConfig::reference();
    let block = Model {
        name: "vit_mlp",
        ops: vec![
            OpDesc::mm(tokens, d, hidden, Precision::Int8),
            OpDesc::mm(tokens, hidden, d, Precision::Int8),
        ],
        scalar_fraction: 0.0,
    };
    let pool = ServePool::new(
        cfg,
        ServeOptions { workers: 2, capacity: 32, ..Default::default() },
    )?;
    let results = pool.run_all((0..REQUESTS).map(|_| Request::model(block.clone())))?;
    let metrics = pool.shutdown();

    let cycles = results[0].stats.cycles;
    println!(
        "ServePool: {} requests on {} workers -> {:.0} req/s host-side \
         ({} batches, {} coalesced, cache {:.0}% hit)",
        metrics.completed,
        metrics.workers,
        metrics.throughput_rps,
        metrics.batches,
        metrics.coalesced,
        100.0 * metrics.cache.hit_rate()
    );
    println!(
        "  latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        metrics.p50_us as f64 / 1e3,
        metrics.p95_us as f64 / 1e3,
        metrics.p99_us as f64 / 1e3
    );
    println!(
        "SPEED silicon estimate: {cycles} cycles/block ({:.2} µs @ {:.2} GHz, \
         {:.0}k blocks/s/instance)",
        cycles as f64 / (cfg.freq_ghz * 1e9) * 1e6,
        cfg.freq_ghz,
        cfg.freq_ghz * 1e9 / cycles as f64 / 1e3
    );
    Ok(())
}
